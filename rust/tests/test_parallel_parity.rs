//! The two-tier parity suite for the fused block-parallel step engine.
//!
//! **Tier 1 — bit-exact** (reference vs fused, *equal* window dtype): the
//! engine's contract (see `optim::Optimizer::step_sharded`) is that fusing
//! the four sweeps into one pass and sharding it across any worker count
//! must not change a single bit of the trajectory: blocks are independent,
//! so partitioning them cannot reassociate any float op, and the store/
//! accumulate kernels are shared between the two paths. Pinned for every
//! `EfMode` x `WinDtype` across 1/2/4/8 workers, through window
//! wrap-around, on dimensions with and without a padded tail block.
//!
//! **Tier 2 — tolerance-bounded** (f32 window vs bf16 window): storing `V`
//! in bf16 rounds each window value to 8 mantissa bits, so the f32 and
//! bf16 trajectories legitimately diverge at the rounding level. The ULP
//! budget: one bf16 round-to-nearest-even carries relative error at most
//! `2^-9`; `z1` is a convex combination of window values (error <= 2^-9),
//! `z2` is quadratic (<= 2^-8, halved back through the sqrt), so each
//! parameter update `u = lr * z1 / (eps + sqrt(z2))` is perturbed by at
//! most ~`2^-8` of its magnitude plus Top-K/EF re-selection effects that
//! error feedback keeps bounded. With exogenous (parameter-independent)
//! gradients the accumulators and Top-K selections coincide exactly —
//! asserted below — leaving the divergence a pure accumulation of
//! AdamStats rounding, bounded by `BF16_TRAJ_TOL` of the accumulated
//! update mass.
//!
//! **Tier 3 — bit-exact across the simd axis** (`Policy::Scalar` vs
//! `Policy::Auto`): the `simd` dispatch layer is a codegen knob, never a
//! numerics knob — whatever level the host resolves, the fused trajectory,
//! the EF state, and the full checkpoint snapshot must match the forced-
//! scalar run bit for bit, at every `WinDtype` x worker count. On a host
//! without a vector level (or built without `--features simd`) both runs
//! resolve to scalar and the tier degenerates to a self-comparison — still
//! a valid (if tautological) gate, and the `make ci` feature matrix runs
//! the suite with the feature on.

use microadam::exec::ExecPool;
use microadam::optim::microadam::{EfMode, MicroAdam, MicroAdamConfig};
use microadam::optim::Optimizer;
use microadam::simd::{Level, Policy};
use microadam::topk::WinDtype;
use microadam::util::rng::Rng;

fn randvec(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0 * s).collect()
}

fn cfg(ef: EfMode, win: WinDtype) -> MicroAdamConfig {
    // small blocks -> many blocks -> real sharding even at 8 workers
    MicroAdamConfig { m: 4, block: 64, density: 0.05, qbucket: 16, ef, win_dtype: win, ..Default::default() }
}

// ---------------------------------------------------------------------------
// Tier 1: bit-exact, reference vs fused at equal dtype
// ---------------------------------------------------------------------------

/// Run `steps` steps of the reference sweep and of the fused engine at
/// `workers`, asserting bitwise-identical params and error norm each step.
fn assert_parity(d: usize, ef: EfMode, win: WinDtype, workers: usize, steps: usize, seed: u64) {
    let pool = ExecPool::new(workers);
    let mut reference = MicroAdam::new(d, cfg(ef, win));
    let mut fused = MicroAdam::new(d, cfg(ef, win));
    let mut rng = Rng::seed_from_u64(seed);
    let mut x_ref = randvec(&mut rng, d, 1.0);
    let mut x_fused = x_ref.clone();
    for s in 0..steps {
        let g = randvec(&mut rng, d, 1.0);
        reference.step_reference(&mut x_ref, &g, 3e-3);
        fused.step_sharded(&mut x_fused, &g, 3e-3, &pool);
        assert_eq!(
            x_ref, x_fused,
            "d={d} {ef:?} {win:?} workers={workers} diverged at step {s}"
        );
        assert_eq!(
            reference.error_norm(),
            fused.error_norm(),
            "d={d} {ef:?} {win:?} workers={workers} EF diverged at step {s}"
        );
    }
    assert_eq!(reference.t(), fused.t());
}

#[test]
fn fused_engine_matches_reference_all_modes_workers_and_dtypes() {
    // past 2*m steps so the ring buffer wraps at least twice
    for win in [WinDtype::Bf16, WinDtype::F32] {
        for ef in [EfMode::Off, EfMode::Dense, EfMode::Quant4] {
            for workers in [1usize, 2, 4, 8] {
                assert_parity(1024, ef, win, workers, 11, 42);
            }
        }
    }
}

#[test]
fn fused_engine_matches_reference_with_padded_tail() {
    // d = 1000 with block 64 pads to 1024: the last shard owns the partial
    // block, where params/grads are shorter than the padded span.
    for win in [WinDtype::Bf16, WinDtype::F32] {
        for ef in [EfMode::Off, EfMode::Dense, EfMode::Quant4] {
            for workers in [1usize, 2, 4, 8] {
                assert_parity(1000, ef, win, workers, 10, 7);
            }
        }
    }
}

#[test]
fn fused_engine_matches_reference_more_workers_than_blocks() {
    // 128 / 64 = 2 blocks but 8 workers: the pool must clamp shards to NB.
    for ef in [EfMode::Off, EfMode::Dense, EfMode::Quant4] {
        assert_parity(128, ef, WinDtype::Bf16, 8, 10, 3);
    }
}

#[test]
fn worker_count_can_change_mid_trajectory() {
    // Shard layout is per-call state, not optimizer state: switching pools
    // between steps must leave the trajectory untouched.
    let d = 512;
    let mut reference = MicroAdam::new(d, cfg(EfMode::Quant4, WinDtype::Bf16));
    let mut fused = MicroAdam::new(d, cfg(EfMode::Quant4, WinDtype::Bf16));
    let mut rng = Rng::seed_from_u64(11);
    let mut x_ref = randvec(&mut rng, d, 1.0);
    let mut x_fused = x_ref.clone();
    for (s, workers) in [1usize, 4, 2, 8, 3, 1, 8].into_iter().enumerate() {
        let pool = ExecPool::new(workers);
        let g = randvec(&mut rng, d, 1.0);
        reference.step_reference(&mut x_ref, &g, 3e-3);
        fused.step_sharded(&mut x_fused, &g, 3e-3, &pool);
        assert_eq!(x_ref, x_fused, "step {s} (workers={workers})");
    }
}

#[test]
fn plain_step_is_the_fused_serial_engine() {
    // Optimizer::step must equal the sharded path at one worker, i.e. the
    // public default entry point is the fused engine.
    let d = 768;
    let pool = ExecPool::new(1);
    let mut a = MicroAdam::new(d, cfg(EfMode::Quant4, WinDtype::Bf16));
    let mut b = MicroAdam::new(d, cfg(EfMode::Quant4, WinDtype::Bf16));
    let mut rng = Rng::seed_from_u64(23);
    let mut xa = randvec(&mut rng, d, 1.0);
    let mut xb = xa.clone();
    for _ in 0..9 {
        let g = randvec(&mut rng, d, 1.0);
        a.step(&mut xa, &g, 1e-2);
        b.step_sharded(&mut xb, &g, 1e-2, &pool);
    }
    assert_eq!(xa, xb);
}

#[test]
fn one_persistent_pool_serves_a_whole_trajectory() {
    // The steady-state shape the rewrite targets: one pool, many steps,
    // workers parked between dispatches — still bit-exact vs reference.
    let d = 1024;
    let pool = ExecPool::new(4);
    let mut reference = MicroAdam::new(d, cfg(EfMode::Quant4, WinDtype::Bf16));
    let mut fused = MicroAdam::new(d, cfg(EfMode::Quant4, WinDtype::Bf16));
    let mut rng = Rng::seed_from_u64(77);
    let mut x_ref = randvec(&mut rng, d, 1.0);
    let mut x_fused = x_ref.clone();
    for s in 0..50 {
        let g = randvec(&mut rng, d, 1.0);
        reference.step_reference(&mut x_ref, &g, 3e-3);
        fused.step_sharded(&mut x_fused, &g, 3e-3, &pool);
        assert_eq!(x_ref, x_fused, "step {s}");
    }
}

// ---------------------------------------------------------------------------
// Tier 2: tolerance-bounded, f32 window vs bf16 window
// ---------------------------------------------------------------------------

/// Documented trajectory budget for f32-vs-bf16 window divergence under
/// exogenous gradients: the divergence is an accumulation of per-step
/// AdamStats rounding at ~2^-8 of each update's magnitude (see the module
/// doc); 2^-5 of the accumulated update mass leaves an 8x margin for
/// rounding interactions across steps without ever excusing a real bug.
const BF16_TRAJ_TOL: f32 = 1.0 / 32.0;

fn l2(a: &[f32]) -> f32 {
    a.iter().map(|v| v * v).sum::<f32>().sqrt()
}

fn l2_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
}

#[test]
fn bf16_window_divergence_bounded_by_update_mass() {
    let d = 1024;
    let steps = 16;
    let lr = 3e-3f32;
    let mut f32_opt = MicroAdam::new(d, cfg(EfMode::Quant4, WinDtype::F32));
    let mut bf16_opt = MicroAdam::new(d, cfg(EfMode::Quant4, WinDtype::Bf16));
    let mut rng = Rng::seed_from_u64(99);
    let mut x_f = randvec(&mut rng, d, 1.0);
    let mut x_b = x_f.clone();
    let mut update_mass = 0f32;
    for s in 0..steps {
        let g = randvec(&mut rng, d, 1.0);
        let before = x_f.clone();
        f32_opt.step(&mut x_f, &g, lr);
        bf16_opt.step(&mut x_b, &g, lr);
        update_mass += l2_diff(&x_f, &before);
        // With parameter-independent gradients the accumulator — and hence
        // the Top-K selection and the EF state — is identical across
        // dtypes: only the stored window values (and so the AdamStats)
        // differ. Sharp invariants first:
        assert_eq!(f32_opt.error_norm(), bf16_opt.error_norm(), "EF must be dtype-independent (step {s})");
        let div = l2_diff(&x_f, &x_b);
        assert!(
            div <= BF16_TRAJ_TOL * update_mass + 1e-6,
            "step {s}: divergence {div} exceeds budget {} ({} update mass)",
            BF16_TRAJ_TOL * update_mass,
            update_mass
        );
    }
    // bf16 must actually round something: a bit-identical run would mean
    // the window never stored a non-representable value (dead storage path)
    assert_ne!(x_f, x_b, "bf16 window had no effect after {steps} steps");
    // and stay a small perturbation relative to the parameter scale
    assert!(l2_diff(&x_f, &x_b) / l2(&x_f) < 1e-2);
}

#[test]
fn bf16_window_tracks_f32_on_a_quadratic() {
    // Closed loop (grads depend on params): selections may flip near ties,
    // but EF keeps the trajectories close — the end-to-end guarantee the
    // optimizer actually needs. Same shape (and a tighter perturbation)
    // than the pinned quant4-vs-dense-EF tracking bound, so the same 5%
    // relative tolerance applies with margin.
    let d = 256;
    let mk = |win| MicroAdam::new(d, cfg(EfMode::Quant4, win));
    let mut a = mk(WinDtype::F32);
    let mut b = mk(WinDtype::Bf16);
    let mut rng = Rng::seed_from_u64(5);
    let mut xa = randvec(&mut rng, d, 1.0);
    let mut xb = xa.clone();
    for _ in 0..30 {
        let ga = xa.clone();
        a.step(&mut xa, &ga, 0.01);
        let gb = xb.clone();
        b.step(&mut xb, &gb, 0.01);
    }
    let rel = l2_diff(&xa, &xb) / l2(&xa);
    assert!(rel < 0.05, "rel diff {rel}");
}

// ---------------------------------------------------------------------------
// Tier 3: bit-exact across the simd axis (Policy::Scalar vs Policy::Auto)
// ---------------------------------------------------------------------------

/// Paper EF mode at a block size past the Top-K prefilter's engagement
/// threshold (128), so a resolved vector level exercises the
/// `count_abs_ge` candidate-thinning path as well as the elementwise
/// kernels.
fn simd_cfg(win: WinDtype, policy: Policy) -> MicroAdamConfig {
    MicroAdamConfig {
        m: 4,
        block: 256,
        density: 0.05,
        qbucket: 16,
        win_dtype: win,
        simd: policy,
        ..Default::default()
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

/// `steps` fused steps under `Policy::Scalar` and `Policy::Auto` on the
/// same gradient stream, asserting bitwise-identical params and EF norm
/// every step and a bitwise-identical checkpoint snapshot at the end.
fn assert_simd_parity(d: usize, win: WinDtype, workers: usize, steps: usize, seed: u64) {
    let pool = ExecPool::new(workers);
    let mut scalar = MicroAdam::new(d, simd_cfg(win, Policy::Scalar));
    let mut auto = MicroAdam::new(d, simd_cfg(win, Policy::Auto));
    assert_eq!(scalar.simd_level(), Level::Scalar, "Policy::Scalar must force the scalar kernels");
    let level = auto.simd_level();
    let mut rng = Rng::seed_from_u64(seed);
    let mut x_s = randvec(&mut rng, d, 1.0);
    let mut x_a = x_s.clone();
    for s in 0..steps {
        let g = randvec(&mut rng, d, 1.0);
        scalar.step_sharded(&mut x_s, &g, 3e-3, &pool);
        auto.step_sharded(&mut x_a, &g, 3e-3, &pool);
        assert_eq!(
            bits(&x_s),
            bits(&x_a),
            "d={d} {win:?} workers={workers} level={level:?} diverged at step {s}"
        );
        assert_eq!(
            scalar.error_norm().to_bits(),
            auto.error_norm().to_bits(),
            "d={d} {win:?} workers={workers} level={level:?} EF diverged at step {s}"
        );
    }
    let (a, b) = (scalar.snapshot().unwrap(), auto.snapshot().unwrap());
    assert_eq!(a.ef, b.ef, "packed EF state diverged ({win:?}, {workers} workers)");
    assert_eq!(bits(&a.qlo), bits(&b.qlo), "EF bucket lo diverged");
    assert_eq!(bits(&a.qhi), bits(&b.qhi), "EF bucket hi diverged");
    assert_eq!(a.w_idx, b.w_idx, "window indices diverged");
    assert_eq!(bits(&a.w_val), bits(&b.w_val), "window values diverged");
    assert_eq!(a.w_bf16, b.w_bf16);
    assert_eq!(a.t, b.t);
}

#[test]
fn simd_auto_matches_forced_scalar_all_dtypes_and_workers() {
    // past 2*m steps so the window ring wraps under both policies
    for win in [WinDtype::Bf16, WinDtype::F32] {
        for workers in [1usize, 2, 4, 8] {
            assert_simd_parity(2048, win, workers, 10, 1234);
        }
    }
}

#[test]
fn simd_auto_matches_forced_scalar_with_padded_tail() {
    // d = 2000 with block 256 pads to 2048: the remainder lanes of every
    // vector kernel run on the partial block each step.
    for win in [WinDtype::Bf16, WinDtype::F32] {
        for workers in [1usize, 2, 4, 8] {
            assert_simd_parity(2000, win, workers, 9, 4321);
        }
    }
}

// ---------------------------------------------------------------------------
// Tier 4: the sharded optimizer zoo holds the same contract
// ---------------------------------------------------------------------------
//
// LDAdam and Adam-mini ride the same block-partitioned engine conventions
// as MicroAdam: `step_sharded` at any worker count must be bit-identical
// to the sequential `step`, and the full state snapshot must agree after
// the trajectory — blocks are carved whole, never reassociated.

use microadam::optim::adammini::{AdamMini, AdamMiniConfig};
use microadam::optim::ldadam::{LdAdam, LdAdamConfig};

/// `steps` steps of sequential `step` vs `step_sharded` at each worker
/// count in {1, 2, 4, 8}, asserting bitwise-identical params every step
/// and an identical state snapshot at the end.
fn assert_zoo_parity<F: Fn() -> Box<dyn Optimizer>>(mk: F, d: usize, steps: usize, seed: u64, label: &str) {
    for workers in [1usize, 2, 4, 8] {
        let pool = ExecPool::new(workers);
        let mut reference = mk();
        let mut sharded = mk();
        let mut rng = Rng::seed_from_u64(seed);
        let mut x_ref = randvec(&mut rng, d, 1.0);
        let mut x_sh = x_ref.clone();
        for s in 0..steps {
            let g = randvec(&mut rng, d, 1.0);
            reference.step(&mut x_ref, &g, 5e-3);
            sharded.step_sharded(&mut x_sh, &g, 5e-3, &pool);
            assert_eq!(x_ref, x_sh, "{label} d={d} workers={workers} diverged at step {s}");
        }
        assert_eq!(reference.t(), sharded.t(), "{label} d={d} workers={workers} t");
        assert_eq!(
            reference.snapshot_state(),
            sharded.snapshot_state(),
            "{label} d={d} workers={workers} state snapshot diverged"
        );
    }
}

/// Small blocks -> many blocks -> real sharding even at 8 workers; the
/// refresh RNG is seeded per (block, t), so worker assignment must not
/// show up in the sketches.
fn ld_cfg() -> LdAdamConfig {
    LdAdamConfig { rank: 2, update_every: 3, block: 64, cols: 8, qbucket: 16, ..Default::default() }
}

#[test]
fn ldadam_sharded_matches_step_all_worker_counts() {
    assert_zoo_parity(|| Box::new(LdAdam::new(1024, ld_cfg())), 1024, 9, 42, "ldadam");
}

#[test]
fn ldadam_sharded_matches_step_with_padded_tail() {
    // d = 1000 with block 64 pads to 1024: the last shard owns the partial
    // block, where params/grads are shorter than the padded span.
    assert_zoo_parity(|| Box::new(LdAdam::new(1000, ld_cfg())), 1000, 8, 7, "ldadam-tail");
}

#[test]
fn adammini_sharded_matches_step_all_worker_counts() {
    let cfg = AdamMiniConfig { block: 64, ..Default::default() };
    assert_zoo_parity(|| Box::new(AdamMini::new(1024, cfg)), 1024, 9, 42, "adammini");
}

#[test]
fn adammini_sharded_matches_step_with_padded_tail() {
    // d = 1003 with block 64: the final block holds 43 real elements and
    // its shared second moment averages over exactly that count.
    let cfg = AdamMiniConfig { block: 64, ..Default::default() };
    assert_zoo_parity(|| Box::new(AdamMini::new(1003, cfg)), 1003, 8, 7, "adammini-tail");
}
