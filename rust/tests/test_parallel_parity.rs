//! Bit-for-bit parity: the fused block-parallel step engine vs the
//! sequential four-sweep reference.
//!
//! The engine's contract (see `optim::Optimizer::step_sharded`) is that
//! sharding the step across any worker count must not change a single bit
//! of the trajectory: blocks are independent, so partitioning them cannot
//! reassociate any float op. These tests pin that for every `EfMode` across
//! 1/2/4/8 workers, through window wrap-around, on dimensions with and
//! without a padded tail block.

use microadam::exec::ExecPool;
use microadam::optim::microadam::{EfMode, MicroAdam, MicroAdamConfig};
use microadam::optim::Optimizer;
use microadam::util::rng::Rng;

fn randvec(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0 * s).collect()
}

fn cfg(ef: EfMode) -> MicroAdamConfig {
    // small blocks -> many blocks -> real sharding even at 8 workers
    MicroAdamConfig { m: 4, block: 64, density: 0.05, qbucket: 16, ef, ..Default::default() }
}

/// Run `steps` steps of the reference sweep and of the fused engine at
/// `workers`, asserting bitwise-identical params and error norm each step.
fn assert_parity(d: usize, ef: EfMode, workers: usize, steps: usize, seed: u64) {
    let pool = ExecPool::new(workers);
    let mut reference = MicroAdam::new(d, cfg(ef));
    let mut fused = MicroAdam::new(d, cfg(ef));
    let mut rng = Rng::seed_from_u64(seed);
    let mut x_ref = randvec(&mut rng, d, 1.0);
    let mut x_fused = x_ref.clone();
    for s in 0..steps {
        let g = randvec(&mut rng, d, 1.0);
        reference.step_reference(&mut x_ref, &g, 3e-3);
        fused.step_sharded(&mut x_fused, &g, 3e-3, &pool);
        assert_eq!(
            x_ref, x_fused,
            "d={d} {ef:?} workers={workers} diverged at step {s}"
        );
        assert_eq!(
            reference.error_norm(),
            fused.error_norm(),
            "d={d} {ef:?} workers={workers} EF diverged at step {s}"
        );
    }
    assert_eq!(reference.t(), fused.t());
}

#[test]
fn fused_engine_matches_reference_all_modes_and_workers() {
    // past 2*m steps so the ring buffer wraps at least twice
    for ef in [EfMode::Off, EfMode::Dense, EfMode::Quant4] {
        for workers in [1usize, 2, 4, 8] {
            assert_parity(1024, ef, workers, 11, 42);
        }
    }
}

#[test]
fn fused_engine_matches_reference_with_padded_tail() {
    // d = 1000 with block 64 pads to 1024: the last shard owns the partial
    // block, where params/grads are shorter than the padded span.
    for ef in [EfMode::Off, EfMode::Dense, EfMode::Quant4] {
        for workers in [1usize, 2, 4, 8] {
            assert_parity(1000, ef, workers, 10, 7);
        }
    }
}

#[test]
fn fused_engine_matches_reference_more_workers_than_blocks() {
    // 128 / 64 = 2 blocks but 8 workers: the pool must clamp shards to NB.
    for ef in [EfMode::Off, EfMode::Dense, EfMode::Quant4] {
        assert_parity(128, ef, 8, 10, 3);
    }
}

#[test]
fn worker_count_can_change_mid_trajectory() {
    // Shard layout is per-call state, not optimizer state: switching pools
    // between steps must leave the trajectory untouched.
    let d = 512;
    let mut reference = MicroAdam::new(d, cfg(EfMode::Quant4));
    let mut fused = MicroAdam::new(d, cfg(EfMode::Quant4));
    let mut rng = Rng::seed_from_u64(11);
    let mut x_ref = randvec(&mut rng, d, 1.0);
    let mut x_fused = x_ref.clone();
    for (s, workers) in [1usize, 4, 2, 8, 3, 1, 8].into_iter().enumerate() {
        let pool = ExecPool::new(workers);
        let g = randvec(&mut rng, d, 1.0);
        reference.step_reference(&mut x_ref, &g, 3e-3);
        fused.step_sharded(&mut x_fused, &g, 3e-3, &pool);
        assert_eq!(x_ref, x_fused, "step {s} (workers={workers})");
    }
}

#[test]
fn plain_step_is_the_fused_serial_engine() {
    // Optimizer::step must equal the sharded path at one worker, i.e. the
    // public default entry point is the fused engine.
    let d = 768;
    let pool = ExecPool::new(1);
    let mut a = MicroAdam::new(d, cfg(EfMode::Quant4));
    let mut b = MicroAdam::new(d, cfg(EfMode::Quant4));
    let mut rng = Rng::seed_from_u64(23);
    let mut xa = randvec(&mut rng, d, 1.0);
    let mut xb = xa.clone();
    for _ in 0..9 {
        let g = randvec(&mut rng, d, 1.0);
        a.step(&mut xa, &g, 1e-2);
        b.step_sharded(&mut xb, &g, 1e-2, &pool);
    }
    assert_eq!(xa, xb);
}
