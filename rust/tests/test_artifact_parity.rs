//! Cross-validation: AOT optimizer artifacts (L2 graph + L1 Pallas kernels,
//! executed via PJRT) vs the native rust implementations of the same math.
//!
//! These tests need `artifacts/` (run `make artifacts`); they are skipped
//! with a notice when the directory is absent so `cargo test` works on a
//! fresh checkout.

use microadam::coordinator::state::{AotAdamWState, AotMicroAdamState};
use microadam::optim::adamw::{AdamW, AdamWConfig};
use microadam::optim::microadam::{MicroAdam, MicroAdamConfig};
use microadam::optim::Optimizer;
use microadam::runtime::{self, lit_f32, Runtime};
use microadam::util::rng::Rng;

const D: usize = 131072; // lm_tiny padded dimension

fn runtime() -> Option<Runtime> {
    std::env::set_var("MICROADAM_QUIET", "1");
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping artifact parity test (no artifacts): {e}");
            None
        }
    }
}

fn randvec(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0 * s).collect()
}

fn rel_err(a: &[f32], b: &[f32]) -> f32 {
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt();
    let den: f32 = b.iter().map(|v| v * v).sum::<f32>().sqrt();
    num / den.max(1e-12)
}

#[test]
fn adamw_artifact_matches_native() {
    let Some(mut rt) = runtime() else { return };
    let name = format!("adamw_step_d{D}");
    if !rt.has(&name) {
        eprintln!("skipping: {name} missing");
        return;
    }
    let meta = rt.meta(&name).unwrap().clone();
    let mut state = AotAdamWState::new(&meta).unwrap();
    let mut native = AdamW::new(D, AdamWConfig::default());

    let mut rng = Rng::seed_from_u64(0);
    let init = randvec(&mut rng, D, 0.5);
    let mut p_aot = lit_f32(&init, &[D]).unwrap();
    let mut p_nat = init;
    for _ in 0..5 {
        let g = randvec(&mut rng, D, 1.0);
        let g_lit = lit_f32(&g, &[D]).unwrap();
        p_aot = state.step(&mut rt, p_aot, g_lit, 1e-3, 0.0).unwrap();
        native.step(&mut p_nat, &g, 1e-3);
    }
    let aot = runtime::to_f32(&p_aot).unwrap();
    let err = rel_err(&aot, &p_nat);
    assert!(err < 1e-5, "adamw parity rel err {err}");
}

#[test]
fn microadam_artifact_matches_native() {
    // The native Algorithm-1 implementation and the AOT graph (Pallas
    // kernels, sort-based Top-K) must produce near-identical trajectories:
    // same block structure, same 4-bit EF, same window semantics. Small
    // drift is allowed for Top-K ties and fp ordering.
    let Some(mut rt) = runtime() else { return };
    let name = format!("microadam_step_d{D}");
    if !rt.has(&name) {
        eprintln!("skipping: {name} missing");
        return;
    }
    let meta = rt.meta(&name).unwrap().clone();
    let mut state = AotMicroAdamState::new(&meta).unwrap();
    // The L2 graph stores window values in f32; compare against the native
    // engine's f32 window mode (the bf16 default is a deliberate storage
    // divergence, tolerance-bounded in test_parallel_parity.rs instead).
    let mut native = MicroAdam::new(D, MicroAdamConfig {
        win_dtype: microadam::topk::WinDtype::F32,
        ..Default::default()
    });
    assert_eq!(state.kb, native.kb(), "artifact and native k_b must agree");

    let mut rng = Rng::seed_from_u64(1);
    let init = randvec(&mut rng, D, 0.5);
    let mut p_aot = lit_f32(&init, &[D]).unwrap();
    let mut p_nat = init;
    for step in 0..8 {
        let g = randvec(&mut rng, D, 1.0);
        let g_lit = lit_f32(&g, &[D]).unwrap();
        p_aot = state.step(&mut rt, p_aot, g_lit, 1e-2, 0.0).unwrap();
        native.step(&mut p_nat, &g, 1e-2);
        let aot = runtime::to_f32(&p_aot).unwrap();
        let err = rel_err(&aot, &p_nat);
        assert!(err < 1e-4, "microadam parity rel err {err} at step {step}");
    }
}

#[test]
fn microadam_artifact_state_snapshot_roundtrip() {
    let Some(mut rt) = runtime() else { return };
    let name = format!("microadam_step_d{D}");
    if !rt.has(&name) {
        return;
    }
    let meta = rt.meta(&name).unwrap().clone();
    let mut state = AotMicroAdamState::new(&meta).unwrap();
    let mut rng = Rng::seed_from_u64(2);
    let mut p = lit_f32(&randvec(&mut rng, D, 0.5), &[D]).unwrap();
    for _ in 0..3 {
        let g = lit_f32(&randvec(&mut rng, D, 1.0), &[D]).unwrap();
        p = state.step(&mut rt, p, g, 1e-2, 0.0).unwrap();
    }
    let snap = state.snapshot().unwrap();
    assert_eq!(snap.t, 3);
    assert_eq!(snap.ef.len(), D / 2);
    // EF is non-trivial after steps
    assert!(snap.ef.iter().any(|&b| b != 0));
    // restore into a fresh state: next step must match byte-for-byte
    let mut state2 = AotMicroAdamState::new(&meta).unwrap();
    state2.restore(&snap).unwrap();
    let g = randvec(&mut rng, D, 1.0);
    let p_after_1 = state
        .step(&mut rt, p.clone(), lit_f32(&g, &[D]).unwrap(), 1e-2, 0.0)
        .unwrap();
    let p_after_2 = state2
        .step(&mut rt, p, lit_f32(&g, &[D]).unwrap(), 1e-2, 0.0)
        .unwrap();
    assert_eq!(
        runtime::to_f32(&p_after_1).unwrap(),
        runtime::to_f32(&p_after_2).unwrap()
    );
}

#[test]
fn microadam_artifact_update_is_sparse() {
    // Paper §3 "Properties": coordinates outside the window union must not
    // move (wd = 0) — verified on the real AOT path.
    let Some(mut rt) = runtime() else { return };
    let name = format!("microadam_step_d{D}");
    if !rt.has(&name) {
        return;
    }
    let meta = rt.meta(&name).unwrap().clone();
    let mut state = AotMicroAdamState::new(&meta).unwrap();
    let mut rng = Rng::seed_from_u64(3);
    let init = randvec(&mut rng, D, 0.5);
    let g = randvec(&mut rng, D, 1.0);
    let p1 = state
        .step(&mut rt, lit_f32(&init, &[D]).unwrap(), lit_f32(&g, &[D]).unwrap(), 1e-2, 0.0)
        .unwrap();
    let p1 = runtime::to_f32(&p1).unwrap();
    let moved = init.iter().zip(&p1).filter(|(a, b)| a != b).count();
    let max_moved = state.m * state.nb * state.kb; // m rows could overlap
    assert!(moved <= max_moved, "moved {moved} > m*nb*kb {max_moved}");
    assert!(moved > 0, "update must move something");
    // at t=1 only one window row is filled: exactly <= nb*kb coords move
    assert!(moved <= state.nb * state.kb, "t=1 moved {moved} > nb*kb");
}
