//! TCP transport parity + fault injection: the multi-host exchange must
//! be a bit-perfect re-plumbing of the loopback engine, and every way a
//! peer can misbehave must fail with a typed error inside its timeout —
//! never a hang, never corrupted rank-0 state.
//!
//! Everything here is pinned to `127.0.0.1` ephemeral ports: no external
//! network is touched, so the suite runs in any sandboxed CI lane.
//!
//! * thread-endpoint TCP runs are **bit-identical** to loopback (loss
//!   series and final parameters) for all three reducers × ranks ∈ {2, 4};
//! * framed bytes measured over the real socket equal
//!   `wire_bytes_per_rank() + FRAME_OVERHEAD` per rank per step;
//! * the actual `microadam train --transport tcp` launcher (separate OS
//!   processes) reproduces the loopback metrics JSONL at ranks = 4 — the
//!   acceptance criterion of the multi-host engine;
//! * fault injection: silent connections, stale-version peers, mid-frame
//!   disconnects, 1-byte-at-a-time slow writers, and mismatched-config
//!   peers — plus the topology links: a mid-ring neighbor disconnect, a
//!   slow hop writer, and a stale-version hello on a tree child link;
//! * streaming: `collect_streaming` yields already-arrived frames (local
//!   first, then arrival order) while a lagging rank is still in flight;
//! * pipelining: the coordinator's `collect` observes out-of-order worker
//!   arrival (a later rank before rank 1) and still returns the
//!   rank-ascending set whose aggregate is bit-identical to sorted-order
//!   loopback.

use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use microadam::coordinator::config::TrainConfig;
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::dist::wire::{self, Frame, PayloadTag, FLAG_HELLO, HELLO_DIGEST_BYTES};
use microadam::dist::{
    build_reducer, tree_tcp_coordinator, DistTrainer, ReducerKind, RingDriver,
    SparseReduceConfig, TcpPending, TcpTransport, Transport, TransportKind, FLAG_HOP,
    FRAME_OVERHEAD,
};
use microadam::exec::ExecPool;
use microadam::optim::OptimizerKind;
use microadam::util::json::Json;

const STEPS: u64 = 8;

fn cfg(ranks: usize, reduce: ReducerKind, transport: TransportKind) -> TrainConfig {
    TrainConfig {
        model: "mlp_tiny".into(),
        optimizer: OptimizerKind::MicroAdam,
        schedule: LrSchedule::Const { lr: 3e-3 },
        steps: STEPS,
        seed: 7,
        log_every: 10_000,
        workers: 2,
        ranks,
        reduce,
        transport,
        ..Default::default()
    }
}

fn bind_local(ranks: usize) -> (TcpPending, String) {
    let pending = TcpPending::bind("127.0.0.1:0", ranks).unwrap();
    let addr = pending.local_addr().unwrap().to_string();
    (pending, addr)
}

/// Loss series (bit patterns) + final params of a loopback run.
fn run_loopback(ranks: usize, reduce: ReducerKind) -> (Vec<u32>, Vec<f32>) {
    let mut t = DistTrainer::new(cfg(ranks, reduce, TransportKind::Loopback)).unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    t.train(&mut logger).unwrap();
    (logger.history.iter().map(|m| m.loss.to_bits()).collect(), t.params_vec())
}

struct EndpointReport {
    losses: Vec<u32>,
    params: Vec<f32>,
    bytes_sent: u64,
    bytes_received: u64,
    wire_per_rank: usize,
    overlap_ms: f64,
}

fn run_endpoint(
    ranks: usize,
    reduce: ReducerKind,
    transport: Box<dyn Transport>,
    rank: usize,
) -> EndpointReport {
    let mut t =
        DistTrainer::with_transport(cfg(ranks, reduce, TransportKind::Tcp), transport, vec![rank])
            .unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    t.train(&mut logger).unwrap();
    EndpointReport {
        losses: logger.history.iter().map(|m| m.loss.to_bits()).collect(),
        params: t.params_vec(),
        bytes_sent: t.transport_bytes_sent(),
        bytes_received: t.transport_bytes_received(),
        wire_per_rank: t.frame_bytes_per_rank() - FRAME_OVERHEAD,
        overlap_ms: t.gather_overlap_ms(),
    }
}

fn run_tcp(ranks: usize, reduce: ReducerKind) -> (EndpointReport, Vec<EndpointReport>) {
    let (pending, addr) = bind_local(ranks);
    let workers: Vec<_> = (1..ranks)
        .map(|r| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let t = TcpTransport::connect(&addr, r, ranks).unwrap();
                run_endpoint(ranks, reduce, Box::new(t), r)
            })
        })
        .collect();
    let coord = run_endpoint(ranks, reduce, Box::new(pending.accept().unwrap()), 0);
    (coord, workers.into_iter().map(|w| w.join().unwrap()).collect())
}

// ---------------------------------------------------------------------------
// Parity: bit-identical to loopback, measured bytes match the accounting
// ---------------------------------------------------------------------------

#[test]
fn tcp_matches_loopback_bitwise() {
    for ranks in [2usize, 4] {
        for reduce in [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK] {
            let (loop_losses, loop_params) = run_loopback(ranks, reduce);
            assert_eq!(loop_losses.len(), STEPS as usize);
            let (coord, workers) = run_tcp(ranks, reduce);
            assert_eq!(coord.losses, loop_losses, "{reduce:?} x{ranks} loss series");
            assert_eq!(coord.params, loop_params, "{reduce:?} x{ranks} final params");
            assert!(coord.overlap_ms >= 0.0);
            for (i, w) in workers.iter().enumerate() {
                assert_eq!(w.params, loop_params, "{reduce:?} x{ranks} worker {}", i + 1);
                assert!(w.losses.is_empty(), "workers run silent");
            }
        }
    }
}

#[test]
fn framed_socket_bytes_match_accounting() {
    // Acceptance criterion: bytes measured over the real TCP socket equal
    // the reducer's accounted wire bytes plus the documented overhead.
    let ranks = 3usize;
    let digest = (FRAME_OVERHEAD + HELLO_DIGEST_BYTES) as u64;
    let hello = FRAME_OVERHEAD as u64;
    let (coord, workers) = run_tcp(ranks, ReducerKind::EfTopK);
    let framed = (coord.wire_per_rank + FRAME_OVERHEAD) as u64;
    for w in &workers {
        // uplink: the one-time rendezvous hello + config-digest frame,
        // then exactly one gradient frame per step
        assert_eq!(w.bytes_sent, STEPS * framed + digest + hello, "worker uplink");
        // downlink: the full bundle for the handshake round and every step
        assert_eq!(w.bytes_received, (STEPS * framed + digest) * ranks as u64, "bundle");
    }
    // the coordinator gathered one frame per worker per round
    assert_eq!(
        coord.bytes_received,
        (STEPS * framed + digest) * (ranks as u64 - 1),
        "coordinator gather"
    );
}

// ---------------------------------------------------------------------------
// Pipelining: out-of-order arrival at the hub
// ---------------------------------------------------------------------------

#[test]
fn pipelined_collect_handles_out_of_order_arrival() {
    let ranks = 4usize;
    let d = 300usize;
    let pool = ExecPool::serial();
    // Reference: compress every rank in-core and aggregate in sorted
    // (loopback) order.
    let mut reference =
        build_reducer(ReducerKind::EfTopK, d, ranks, SparseReduceConfig::default());
    let grads: Vec<Vec<f32>> = (0..ranks)
        .map(|r| (0..d).map(|i| ((i + r * 31) % 17) as f32 * 0.1 - 0.8).collect())
        .collect();
    let payloads: Vec<Vec<u8>> =
        (0..ranks).map(|r| reference.compress_payload(r, &grads[r])).collect();
    let mut ref_out = vec![0f32; d];
    reference.aggregate_payloads(&payloads, &mut ref_out, &pool).unwrap();

    let (pending, addr) = bind_local(ranks);
    let handles: Vec<_> = (1..ranks)
        .map(|r| {
            let addr = addr.clone();
            let payload = payloads[r].clone();
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, r, ranks).unwrap();
                if r == 1 {
                    // rank 1 lags (generously, so scheduler noise cannot
                    // flip the ordering): ranks 2 and 3 reach the hub first
                    std::thread::sleep(Duration::from_millis(1500));
                }
                let f = Frame {
                    rank: r as u16,
                    step: 1,
                    tag: PayloadTag::EfTopK,
                    flags: 0,
                    loss: 0.0,
                    payload,
                    stats: vec![],
                };
                t.exchange(vec![f]).unwrap().len()
            })
        })
        .collect();
    let mut coord = pending.accept().unwrap();
    let f0 = Frame {
        rank: 0,
        step: 1,
        tag: PayloadTag::EfTopK,
        flags: 0,
        loss: 0.0,
        payload: payloads[0].clone(),
        stats: vec![],
    };
    let frames = coord.exchange(vec![f0]).unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), ranks);
    }
    // collect returned the rank-ascending set regardless of arrival order
    for (r, f) in frames.iter().enumerate() {
        assert_eq!(f.rank as usize, r);
    }
    // ... and the hub really did observe a later rank before rank 1
    let arrival = coord.last_arrival_order().to_vec();
    assert_eq!(arrival.len(), ranks - 1);
    assert_ne!(arrival[0], 1, "a fast rank should have arrived before the lagging rank 1");
    assert_eq!(*arrival.last().unwrap(), 1, "rank 1 arrived last: {arrival:?}");
    assert!(coord.overlap_ms() >= 0.0, "overlap is recorded, never negative");
    // the gathered payloads aggregate bit-identically to sorted-order
    // loopback (arrival order cannot leak into the math)
    let gathered: Vec<Vec<u8>> = frames.into_iter().map(|f| f.payload).collect();
    assert_eq!(gathered, payloads);
    let mut agg = build_reducer(ReducerKind::EfTopK, d, ranks, SparseReduceConfig::default());
    let mut out = vec![0f32; d];
    agg.aggregate_payloads(&gathered, &mut out, &pool).unwrap();
    assert_eq!(out, ref_out);
}

// ---------------------------------------------------------------------------
// Fault injection: every misbehaving peer fails typed, inside its timeout
// ---------------------------------------------------------------------------

/// A bound on "did not hang": every fault below must surface well before
/// the transport's 120 s peer timeout.
const FAULT_BUDGET: Duration = Duration::from_secs(30);

#[test]
fn silent_connection_cannot_hold_the_rendezvous() {
    let (mut pending, addr) = bind_local(2);
    pending.set_hello_wait(Duration::from_millis(300));
    // connect, never send the hello — hold the socket open so the failure
    // is the bounded hello wait, not a disconnect
    let _silent = TcpStream::connect(&addr).unwrap();
    let t0 = Instant::now();
    let err = pending.accept().err().expect("silent peer must be rejected");
    assert!(t0.elapsed() < FAULT_BUDGET, "accept hung: {:?}", t0.elapsed());
    let msg = format!("{err:#}");
    assert!(msg.contains("hello"), "{msg}");
}

#[test]
fn stale_version_peer_is_rejected_at_hello() {
    let (pending, addr) = bind_local(2);
    let mut stale = TcpStream::connect(&addr).unwrap();
    let mut bytes = Frame::hello(1).encode();
    bytes[4] = 2; // version field: speak v2 at a v1 receiver
    stale.write_all(&bytes).unwrap();
    let t0 = Instant::now();
    let err = pending.accept().err().expect("stale-version peer must be rejected");
    assert!(t0.elapsed() < FAULT_BUDGET);
    let msg = format!("{err:#}");
    assert!(msg.contains("version"), "{msg}");
}

#[test]
fn mid_frame_disconnect_is_a_typed_error() {
    let ranks = 2usize;
    let (pending, addr) = bind_local(ranks);
    let worker = std::thread::spawn(move || {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&Frame::hello(1).encode()).unwrap();
        // begin a legitimate frame, then vanish mid-payload
        let f = Frame {
            rank: 1,
            step: 1,
            tag: PayloadTag::TopK,
            flags: 0,
            loss: 0.5,
            payload: vec![7u8; 64],
            stats: vec![],
        };
        let bytes = f.encode();
        s.write_all(&bytes[..bytes.len() / 2]).unwrap();
        // s drops here: mid-frame disconnect
    });
    let mut coord = pending.accept().unwrap();
    worker.join().unwrap();
    let mine = Frame {
        rank: 0,
        step: 1,
        tag: PayloadTag::TopK,
        flags: 0,
        loss: 0.5,
        payload: vec![1u8; 64],
        stats: vec![],
    };
    let t0 = Instant::now();
    let err = coord.exchange(vec![mine]).err().expect("disconnect must fail the gather");
    assert!(t0.elapsed() < FAULT_BUDGET, "gather hung: {:?}", t0.elapsed());
    let msg = format!("{err:#}");
    assert!(msg.contains("gather from rank 1"), "{msg}");
    assert!(msg.contains("truncated"), "typed truncation, got: {msg}");
}

#[test]
fn slow_writer_partial_segments_still_parse() {
    // A worker that trickles its frame one byte at a time exercises the
    // incremental FrameReader over real TCP segment boundaries; the
    // gather must reassemble the identical frame.
    let ranks = 2usize;
    let (pending, addr) = bind_local(ranks);
    let f1 = Frame {
        rank: 1,
        step: 1,
        tag: PayloadTag::TopK,
        flags: 0,
        loss: 2.5,
        payload: (0..48).collect(),
        stats: vec![],
    };
    let expect = f1.clone();
    let worker = std::thread::spawn(move || {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_nodelay(true).unwrap();
        s.write_all(&Frame::hello(1).encode()).unwrap();
        for (i, b) in f1.encode().iter().enumerate() {
            s.write_all(&[*b]).unwrap();
            if i % 16 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        // hold the socket open until the coordinator is done reading
        std::thread::sleep(Duration::from_millis(500));
    });
    let mut coord = pending.accept().unwrap();
    let mine = Frame {
        rank: 0,
        step: 1,
        tag: PayloadTag::TopK,
        flags: 0,
        loss: 0.5,
        payload: vec![1u8; 48],
        stats: vec![],
    };
    let frames = coord.exchange(vec![mine.clone()]).unwrap();
    worker.join().unwrap();
    assert_eq!(frames.len(), 2);
    assert_eq!(frames[0], mine);
    assert_eq!(frames[1], expect, "trickled frame reassembled bit-exactly");
}

#[test]
fn mismatched_worker_config_is_rejected_at_handshake() {
    // A hand-started worker with a different seed must fail the round-0
    // config-digest exchange on BOTH endpoints — never train divergently.
    let (pending, addr) = bind_local(2);
    let worker = std::thread::spawn(move || {
        let t = TcpTransport::connect(&addr, 1, 2).unwrap();
        let mut bad = cfg(2, ReducerKind::EfTopK, TransportKind::Tcp);
        bad.seed = 999; // trajectory-relevant mismatch
        DistTrainer::with_transport(bad, Box::new(t), vec![1]).err().map(|e| e.to_string())
    });
    let good = cfg(2, ReducerKind::EfTopK, TransportKind::Tcp);
    let coord = DistTrainer::with_transport(good, Box::new(pending.accept().unwrap()), vec![0]);
    let coord_err = coord.err().expect("coordinator must reject the mismatch").to_string();
    assert!(coord_err.contains("digest"), "{coord_err}");
    let worker_err = worker.join().unwrap().expect("worker must reject the mismatch");
    assert!(worker_err.contains("digest"), "{worker_err}");
}

// ---------------------------------------------------------------------------
// Topology faults: ring hops and tree links fail typed too
// ---------------------------------------------------------------------------

/// One ephemeral-port localhost TCP link: `(connect side, accept side)`.
fn tcp_pair() -> (TcpStream, TcpStream) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let a = TcpStream::connect(addr).unwrap();
    a.set_nodelay(true).unwrap();
    let (b, _) = listener.accept().unwrap();
    b.set_nodelay(true).unwrap();
    (a, b)
}

/// The dense partial-aggregate the ring fold closure runs: f32 LE payload
/// added coordinate-wise into the growing accumulator.
fn dense_fold(payload: &[u8], acc: &mut Vec<f32>) -> anyhow::Result<()> {
    if acc.is_empty() {
        acc.resize(payload.len() / 4, 0.0);
    }
    for (i, c) in payload.chunks_exact(4).enumerate() {
        acc[i] += f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

#[test]
fn mid_ring_neighbor_disconnect_is_a_typed_error() {
    // Rank 1 of 3 waits on its predecessor's reduction hop; the
    // predecessor vanishes instead. The hop read must fail typed (the
    // truncated-frame error, naming the predecessor) inside the budget —
    // never hang the ring.
    let (next, _next_peer) = tcp_pair();
    let (prev, prev_peer) = tcp_pair();
    let mut ring = RingDriver::from_streams("tcp-ring", 1, 3, next, prev).unwrap();
    drop(prev_peer); // mid-ring neighbor disconnect
    let mine = Frame {
        rank: 1,
        step: 3,
        tag: PayloadTag::Dense,
        flags: 0,
        loss: 0.5,
        payload: 1.0f32.to_le_bytes().to_vec(),
        stats: vec![],
    };
    ring.post_send(vec![mine]).unwrap();
    let t0 = Instant::now();
    let err = ring
        .collect_reduced(&mut dense_fold)
        .err()
        .expect("a vanished predecessor must fail the hop");
    assert!(t0.elapsed() < FAULT_BUDGET, "ring hop hung: {:?}", t0.elapsed());
    let msg = format!("{err:#}");
    assert!(msg.contains("predecessor rank 0"), "{msg}");
    assert!(msg.contains("truncated"), "typed truncation, got: {msg}");
}

#[test]
fn slow_hop_writer_reassembles_the_hop_bitwise() {
    // The last rank of a 3-ring receives its predecessor's hop frame one
    // byte at a time (worst-case TCP segmentation of the HOP prefix and
    // partial payload); the fold must reassemble it bit-exactly, fold the
    // local term in, and emit the finished FLAG_HOP result around the ring.
    let (next, mut next_peer) = tcp_pair();
    let (prev, mut prev_peer) = tcp_pair();
    let mut ring = RingDriver::from_streams("tcp-ring", 2, 3, next, prev).unwrap();
    let hop = Frame {
        rank: 1,
        step: 5,
        tag: PayloadTag::Dense,
        flags: FLAG_HOP,
        loss: 1.5,
        payload: wire::hop_payload(2, &[10.0, 20.0]),
        stats: vec![],
    };
    let writer = std::thread::spawn(move || {
        for (i, b) in hop.encode().iter().enumerate() {
            prev_peer.write_all(&[*b]).unwrap();
            if i % 16 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        // hold the link open until the ring endpoint is done reading
        std::thread::sleep(Duration::from_millis(500));
    });
    let mut payload = Vec::new();
    payload.extend_from_slice(&1.0f32.to_le_bytes());
    payload.extend_from_slice(&2.0f32.to_le_bytes());
    let mine = Frame {
        rank: 2,
        step: 5,
        tag: PayloadTag::Dense,
        flags: 0,
        loss: 0.25,
        payload,
        stats: vec![],
    };
    ring.post_send(vec![mine]).unwrap();
    let result = ring.collect_reduced(&mut dense_fold).unwrap();
    writer.join().unwrap();
    assert_eq!(result.len(), 1, "the in-ring reduction returns one finished frame");
    let out = &result[0];
    assert_eq!(out.rank, 2);
    assert_ne!(out.flags & FLAG_HOP, 0, "finished frame carries the hop flag");
    assert_eq!(out.loss, 1.5 + 0.25, "loss folds along the hop chain");
    let (fan_in, partial) = wire::hop_from_payload(&out.payload).unwrap();
    assert_eq!(fan_in, 3, "all three ranks folded");
    assert_eq!(partial, vec![11.0, 22.0], "trickled partial folded bit-exactly");
    // ... and the successor received the identical finished frame
    let forwarded = Frame::read_from(&mut next_peer).unwrap();
    assert_eq!(&forwarded, out);
}

#[test]
fn stale_version_hello_from_tree_child_is_rejected() {
    // A worker speaks wire v1 at the star rendezvous, then dials its tree
    // parent with a v2 hello (CRC re-sealed, so the *version* check is
    // what fires). The tree wiring must reject it typed, inside the
    // budget.
    let (pending, addr) = bind_local(2);
    let child = std::thread::spawn(move || {
        // legitimate star rendezvous: hello, then the link-table exchange
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&Frame::hello(1).encode()).unwrap();
        let link = Frame {
            rank: 1,
            step: 0,
            tag: PayloadTag::Dense,
            flags: FLAG_HELLO,
            loss: 0.0,
            payload: b"127.0.0.1:1".to_vec(), // leaf: never dialed
            stats: vec![],
        };
        s.write_all(&link.encode()).unwrap();
        let table = Frame::read_from(&mut s).unwrap();
        let addrs = String::from_utf8(table.payload).unwrap();
        let parent = addrs.lines().next().unwrap().to_string();
        // dial the parent link with a version-2 hello, CRC intact
        let mut bytes = Frame::hello(1).encode();
        bytes[4] = 2;
        let n = bytes.len();
        let crc = wire::crc32(&bytes[..n - 4]).to_le_bytes();
        bytes[n - 4..].copy_from_slice(&crc);
        let mut p = TcpStream::connect(&parent).unwrap();
        p.write_all(&bytes).unwrap();
        // hold both sockets open so the failure is the version check, not
        // a disconnect
        std::thread::sleep(Duration::from_millis(2000));
    });
    let t0 = Instant::now();
    let err = tree_tcp_coordinator(pending)
        .err()
        .expect("a stale-version tree child must be rejected");
    assert!(t0.elapsed() < FAULT_BUDGET, "tree wiring hung: {:?}", t0.elapsed());
    let msg = format!("{err:#}");
    assert!(msg.contains("version"), "{msg}");
    child.join().unwrap();
}

// ---------------------------------------------------------------------------
// Streaming decode: frames surface in arrival order, under the gather
// ---------------------------------------------------------------------------

#[test]
fn streaming_collect_yields_frames_before_the_round_completes() {
    // One rank lags far behind the others. `collect_streaming` must hand
    // the coordinator every already-arrived frame (local first, then
    // arrival order) while the laggard is still in flight — that early
    // delivery is exactly the decode/gather overlap the trainer banks.
    let ranks = 3usize;
    let (pending, addr) = bind_local(ranks);
    let handles: Vec<_> = (1..ranks)
        .map(|r| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, r, ranks).unwrap();
                if r == 1 {
                    // generous lag so scheduler noise cannot flip the order
                    std::thread::sleep(Duration::from_millis(1200));
                }
                let f = Frame {
                    rank: r as u16,
                    step: 1,
                    tag: PayloadTag::Dense,
                    flags: 0,
                    loss: 0.0,
                    payload: vec![r as u8; 40],
                    stats: vec![],
                };
                t.exchange(vec![f]).unwrap().len()
            })
        })
        .collect();
    let mut coord = pending.accept().unwrap();
    let f0 = Frame {
        rank: 0,
        step: 1,
        tag: PayloadTag::Dense,
        flags: 0,
        loss: 0.0,
        payload: vec![0u8; 40],
        stats: vec![],
    };
    coord.post_send(vec![f0]).unwrap();
    let mut events: Vec<(u16, Instant)> = Vec::new();
    let frames = coord
        .collect_streaming(&mut |f: &Frame| {
            events.push((f.rank, Instant::now()));
            Ok(())
        })
        .unwrap();
    let gather_done = Instant::now();
    for h in handles {
        assert_eq!(h.join().unwrap(), ranks);
    }
    // the returned set is still the rank-ascending gather, payloads intact
    assert_eq!(frames.len(), ranks);
    for (r, f) in frames.iter().enumerate() {
        assert_eq!(f.rank as usize, r);
        assert_eq!(f.payload, vec![r as u8; 40]);
    }
    // callbacks ran in arrival order: the locally-hosted frame first, the
    // fast rank 2 next, the lagging rank 1 last
    let order: Vec<u16> = events.iter().map(|(r, _)| *r).collect();
    assert_eq!(order, vec![0, 2, 1], "arrival order, local first: {order:?}");
    // ... and the fast frames surfaced long before the round completed —
    // the decode window under the gather tail is real, not zero
    let lead = gather_done.duration_since(events[1].1);
    assert!(
        lead > Duration::from_millis(300),
        "rank 2's frame should stream out well before the lagging gather ends, lead {lead:?}"
    );
}

// ---------------------------------------------------------------------------
// True multi-process: the real `microadam train --transport tcp` launcher
// ---------------------------------------------------------------------------

fn unique_path(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "microadam-tcppar-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::SeqCst)
    ))
}

/// Extract the (step, loss-as-string) series and the final_loss record
/// from a metrics JSONL file. Losses compare as their serialized strings:
/// equal f32 bits serialize identically, so string equality is bit
/// equality.
fn metrics_series(path: &std::path::Path) -> (Vec<(u64, String)>, Option<String>) {
    let text = std::fs::read_to_string(path).unwrap();
    let mut series = Vec::new();
    let mut final_loss = None;
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        if let (Some(step), Some(loss)) = (j.get("step"), j.get("loss")) {
            series.push((step.as_f64().unwrap() as u64, loss.to_string()));
        }
        if let Some(fl) = j.get("final_loss") {
            final_loss = Some(fl.to_string());
        }
    }
    (series, final_loss)
}

fn launch(transport: &str, out: &std::path::Path) {
    let status = std::process::Command::new(env!("CARGO_BIN_EXE_microadam"))
        .args([
            "train",
            "--model",
            "mlp_tiny",
            "--optimizer",
            "micro-adam",
            "--ranks",
            "4",
            "--reduce",
            "eftopk",
            "--transport",
            transport,
            "--steps",
            "8",
            "--seed",
            "7",
            "--workers",
            "2",
            "--lr",
            "3e-3",
            "--out",
        ])
        .arg(out)
        .status()
        .expect("spawn microadam train");
    assert!(status.success(), "microadam train --transport {transport} failed");
}

#[test]
fn launcher_processes_match_loopback_metrics() {
    // The acceptance criterion: `microadam train --ranks 4 --transport
    // tcp` (loopback addresses, ephemeral port, real worker processes)
    // produces metrics JSONL bit-identical to `--transport loopback`
    // with the same seeds.
    let dir = unique_path("launch");
    std::fs::create_dir_all(&dir).unwrap();
    let loop_out = dir.join("loopback.jsonl");
    launch("loopback", &loop_out);
    let (loop_series, loop_final) = metrics_series(&loop_out);
    assert_eq!(loop_series.len(), 8);
    let out = dir.join("tcp.jsonl");
    launch("tcp", &out);
    let (series, final_loss) = metrics_series(&out);
    assert_eq!(series, loop_series, "tcp per-step losses");
    assert_eq!(final_loss, loop_final, "tcp final loss");
    let _ = std::fs::remove_dir_all(&dir);
}
