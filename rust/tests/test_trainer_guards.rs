//! Trainer input-validation guards (checkpoint-resume corruption, empty
//! eval, NaN logits). These construct a real `Trainer` over a
//! manifest-only fixture directory — no compiled artifacts and no PJRT
//! needed, because none of the guarded paths reach `execute_named`.

use microadam::coordinator::config::{OptBackend, TrainConfig};
use microadam::coordinator::trainer::Trainer;
use microadam::optim::OptimizerKind;

/// A minimal manifest: a transformer_cls fwd/bwd entry (layout: one 8x7
/// tensor padded to 64) + its logits artifact, and an lm entry for the
/// classifier-only eval guard.
const MANIFEST: &str = r#"{
  "artifacts": {
    "cls_fixture": {
      "file": "cls_fixture.hlo",
      "kind": "fwdbwd",
      "model": "transformer_cls",
      "inputs": [
        {"name": "params", "dtype": "float32", "shape": [64]},
        {"name": "tokens", "dtype": "int32", "shape": [4, 8]},
        {"name": "labels", "dtype": "int32", "shape": [4]}
      ],
      "outputs": ["loss", "grads"],
      "config": {"vocab": 32, "n_classes": 3},
      "layout": {
        "d_padded": 64,
        "params": [
          {"name": "w", "shape": [8, 7], "offset": 0, "init": "normal", "init_std": 0.02}
        ]
      }
    },
    "cls_fixture_logits": {
      "file": "cls_fixture_logits.hlo",
      "kind": "infer",
      "inputs": [
        {"name": "params", "dtype": "float32", "shape": [64]},
        {"name": "tokens", "dtype": "int32", "shape": [4, 8]}
      ],
      "outputs": ["logits"]
    },
    "lm_fixture": {
      "file": "lm_fixture.hlo",
      "kind": "fwdbwd",
      "model": "transformer_lm",
      "inputs": [
        {"name": "params", "dtype": "float32", "shape": [64]},
        {"name": "tokens", "dtype": "int32", "shape": [2, 16]},
        {"name": "targets", "dtype": "int32", "shape": [2, 16]}
      ],
      "outputs": ["loss", "grads"],
      "config": {"vocab": 32},
      "layout": {
        "d_padded": 64,
        "params": [
          {"name": "w", "shape": [8, 7], "offset": 0, "init": "normal", "init_std": 0.02}
        ]
      }
    },
    "lm_fixture_logits": {
      "file": "lm_fixture_logits.hlo",
      "kind": "infer",
      "inputs": [
        {"name": "params", "dtype": "float32", "shape": [64]},
        {"name": "tokens", "dtype": "int32", "shape": [2, 16]}
      ],
      "outputs": ["logits"]
    }
  }
}"#;

/// Write the fixture manifest into a fresh temp dir and return its path.
fn fixture_dir(tag: &str) -> String {
    let dir = format!("/tmp/microadam_guard_fixture_{tag}_{}", std::process::id());
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(format!("{dir}/manifest.json"), MANIFEST).unwrap();
    dir
}

fn fixture_trainer(tag: &str, model: &str) -> (Trainer, String) {
    let dir = fixture_dir(tag);
    let cfg = TrainConfig {
        model: model.into(),
        optimizer: OptimizerKind::MicroAdam,
        backend: OptBackend::Native,
        artifacts_dir: dir.clone(),
        ..Default::default()
    };
    (Trainer::new(cfg).unwrap(), dir)
}

#[test]
fn set_params_rejects_length_mismatch() {
    let (mut trainer, dir) = fixture_trainer("setparams", "cls_fixture");
    // too short (truncated checkpoint), too long (foreign model)
    for n in [0usize, 63, 65, 128] {
        let err = trainer.set_params(&vec![0.0; n]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("does not match"), "n={n}: {msg}");
        assert!(msg.contains("64"), "n={n}: {msg}");
    }
    // the exact length is accepted
    trainer.set_params(&vec![0.5; 64]).unwrap();
    assert_eq!(trainer.params_vec().unwrap(), vec![0.5; 64]);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn eval_accuracy_rejects_empty_eval() {
    let (mut trainer, dir) = fixture_trainer("emptyeval", "cls_fixture");
    let err = trainer.eval_accuracy(0).unwrap_err();
    assert!(format!("{err:#}").contains("empty eval"), "{err:#}");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn eval_accuracy_is_classifier_only() {
    let (mut trainer, dir) = fixture_trainer("lmeval", "lm_fixture");
    let err = trainer.eval_accuracy(1).unwrap_err();
    assert!(format!("{err:#}").contains("classifier"), "{err:#}");
    let _ = std::fs::remove_dir_all(dir);
}
