//! Adversarial bit-exactness suite for the `simd::` dispatch layer.
//!
//! Every kernel behind a [`microadam::simd`] dispatcher is run at
//! [`Level::Scalar`] and at every level in [`active_levels`] over inputs
//! chosen to break value-level shortcuts a vectorizer might be tempted
//! into: signaling and payload-carrying NaNs, both infinities,
//! subnormals, negative zero, and the extreme finite values — compared
//! *by bits*, so `NaN == NaN` excuses nothing and `-0.0 == 0.0` hides
//! nothing. Each elementwise kernel also sweeps the remainder lanes:
//! lengths 0, 1, lanes-1, lanes, lanes+1 (for the widest lane count in
//! play, 8 x f32) and a large power of two, so the vector body, the
//! scalar tail, and the empty case are all pinned.
//!
//! On a host that resolves no vector level (no `--features simd`, an
//! unsupported cpu, or `MICROADAM_SIMD=scalar`), `active_levels()` is
//! just `[Scalar]` and the suite degenerates to self-comparison; the
//! `make ci` feature matrix runs it with the feature enabled.

use microadam::quant::{BucketStats, Quant4};
use microadam::simd::{self, active_levels, Level};
use microadam::topk::{self, topk_abs_block_with};
use microadam::util::bf16::{bf16_to_f32, f32_to_bf16};

/// Adversarial f32 bit patterns: signaling NaN, payload qNaNs of both
/// signs, both infinities, the smallest subnormal, the largest negative
/// subnormal, both zeros, the extreme finites, and a few plain values.
const ADVERSARIAL_BITS: &[u32] = &[
    0x7F80_0001, // sNaN
    0x7FC1_2345, // qNaN with payload
    0xFFC1_2345, // negative qNaN with payload
    0x7F80_0000, // +inf
    0xFF80_0000, // -inf
    0x0000_0001, // smallest positive subnormal
    0x807F_FFFF, // largest-magnitude negative subnormal
    0x8000_0000, // -0.0
    0x0000_0000, // +0.0
    0x7F7F_FFFF, // max finite
    0xFF7F_FFFF, // min finite
    0x3F80_0000, // 1.0
    0xBF00_0000, // -0.5
    0x00A0_0000, // small subnormal-adjacent normal
];

/// Remainder-lane length sweep around the widest vector width in play
/// (8 x f32 for AVX2), plus empty and a large power of two.
const LANE_SWEEP: &[usize] = &[0, 1, 7, 8, 9, 1 << 15];

/// Deterministic adversarial vector: the pattern table tiled with a
/// varying mix of ordinary values so vector and remainder lanes both see
/// specials at every alignment.
fn adversarial(n: usize, salt: u32) -> Vec<f32> {
    (0..n)
        .map(|i| {
            if i % 3 == 0 {
                f32::from_bits(ADVERSARIAL_BITS[(i / 3 + salt as usize) % ADVERSARIAL_BITS.len()])
            } else {
                // LCG-ish ordinary values, sign-alternating
                let x = (i as u32).wrapping_mul(2654435761).wrapping_add(salt);
                ((x % 2001) as f32 - 1000.0) / 300.0
            }
        })
        .collect()
}

fn bits32(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn bf16_round_and_widen_bit_exact_across_levels() {
    for &n in LANE_SWEEP {
        let xs = adversarial(n, 1);
        let mut base = vec![0u16; n];
        simd::bf16_round(Level::Scalar, &xs, &mut base);
        // The scalar converter is the oracle for the rounded bits too:
        // round-to-nearest-even with NaNs quieted, elementwise.
        for (i, (&x, &b)) in xs.iter().zip(&base).enumerate() {
            assert_eq!(b, f32_to_bf16(x), "lane {i} disagrees with the scalar converter");
        }
        let mut base_wide = vec![0f32; n];
        simd::bf16_widen(Level::Scalar, &base, &mut base_wide);
        for level in active_levels() {
            let mut got = vec![0u16; n];
            simd::bf16_round(level, &xs, &mut got);
            assert_eq!(got, base, "bf16_round n={n} level={level:?}");
            let mut wide = vec![0f32; n];
            simd::bf16_widen(level, &got, &mut wide);
            assert_eq!(bits32(&wide), bits32(&base_wide), "bf16_widen n={n} level={level:?}");
        }
        // Round-trip through storage must be the identity on the bf16
        // representable set (inf, -0.0, subnormal-with-8-bit-mantissa).
        for &v in &[f32::INFINITY, f32::NEG_INFINITY, -0.0f32, 1.0, bf16_to_f32(0x0001)] {
            assert_eq!(
                bf16_to_f32(f32_to_bf16(v)).to_bits(),
                v.to_bits(),
                "representable value {v:?} not preserved"
            );
        }
    }
}

#[test]
fn quant4_pack_unpack_bit_exact_across_levels() {
    let q = Quant4::new(16);
    for &n in &[0usize, 16, 48, 4096, 1 << 15] {
        let xs = adversarial(n, 2);
        let mut base_packed = vec![0u8; n / 2];
        let mut base_stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; n / 16];
        simd::quant4_quantize(Level::Scalar, &q, &xs, &mut base_packed, &mut base_stats);
        let mut base_out = adversarial(n, 3);
        simd::quant4_dequantize_add(Level::Scalar, &q, &base_packed, &base_stats, &mut base_out);
        for level in active_levels() {
            let mut packed = vec![0u8; n / 2];
            let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; n / 16];
            simd::quant4_quantize(level, &q, &xs, &mut packed, &mut stats);
            assert_eq!(packed, base_packed, "packed codes n={n} level={level:?}");
            for (i, (s, b)) in stats.iter().zip(&base_stats).enumerate() {
                assert_eq!(
                    (s.lo.to_bits(), s.hi.to_bits()),
                    (b.lo.to_bits(), b.hi.to_bits()),
                    "bucket {i} stats n={n} level={level:?}"
                );
            }
            // dequantize_add accumulates into a non-zero slab so the add
            // itself (not just the decode) is under test.
            let mut out = adversarial(n, 3);
            simd::quant4_dequantize_add(level, &q, &packed, &stats, &mut out);
            assert_eq!(bits32(&out), bits32(&base_out), "dequantize_add n={n} level={level:?}");
        }
    }
}

#[test]
fn stats_accum_bit_exact_across_levels() {
    let block = 256usize;
    let k = 41usize;
    // Gathered indices with deliberate duplicates: accumulation order for
    // a repeated index is part of the contract.
    let idx: Vec<u16> = (0..k as u16).map(|i| ((i * 37) % (block as u16)) / 2 * 2).collect();
    let val_f: Vec<f32> = adversarial(k, 4);
    // bf16 payloads straight from the bit table (sNaN, inf, subnormal,
    // -0.0 all exist at 16 bits too).
    let val_b: Vec<u16> = (0..k)
        .map(|i| {
            if i % 2 == 0 {
                (ADVERSARIAL_BITS[i % ADVERSARIAL_BITS.len()] >> 16) as u16
            } else {
                f32_to_bf16(val_f[i])
            }
        })
        .collect();
    let (w1, w2) = (0.1875f32, 0.8125f32);

    let mut base1 = adversarial(block, 5);
    let mut base2 = adversarial(block, 6);
    simd::stats_accum_f32(Level::Scalar, &idx, &val_f, w1, w2, &mut base1, &mut base2);
    for level in active_levels() {
        let mut z1 = adversarial(block, 5);
        let mut z2 = adversarial(block, 6);
        simd::stats_accum_f32(level, &idx, &val_f, w1, w2, &mut z1, &mut z2);
        assert_eq!(bits32(&z1), bits32(&base1), "stats_accum_f32 z1 level={level:?}");
        assert_eq!(bits32(&z2), bits32(&base2), "stats_accum_f32 z2 level={level:?}");
    }

    let mut base1 = adversarial(block, 7);
    let mut base2 = adversarial(block, 8);
    simd::stats_accum_bf16(Level::Scalar, &idx, &val_b, w1, w2, &mut base1, &mut base2);
    for level in active_levels() {
        let mut z1 = adversarial(block, 7);
        let mut z2 = adversarial(block, 8);
        simd::stats_accum_bf16(level, &idx, &val_b, w1, w2, &mut z1, &mut z2);
        assert_eq!(bits32(&z1), bits32(&base1), "stats_accum_bf16 z1 level={level:?}");
        assert_eq!(bits32(&z2), bits32(&base2), "stats_accum_bf16 z2 level={level:?}");
    }
}

#[test]
fn adam_update_bit_exact_across_levels() {
    for &n in LANE_SWEEP {
        // z2 includes negatives -> sqrt(NaN) lanes; params include specials.
        let z1 = adversarial(n, 9);
        let z2 = adversarial(n, 10);
        let mut base = adversarial(n, 11);
        simd::adam_update(Level::Scalar, &mut base, &z1, &z2, 1e-3, 1e-8, 0.9995);
        for level in active_levels() {
            let mut params = adversarial(n, 11);
            simd::adam_update(level, &mut params, &z1, &z2, 1e-3, 1e-8, 0.9995);
            assert_eq!(bits32(&params), bits32(&base), "adam_update n={n} level={level:?}");
        }
    }
}

#[test]
fn count_abs_ge_matches_scalar_on_specials() {
    let block = adversarial(512, 12);
    // Thresholds bracketing the interesting exponent boundaries: zero,
    // smallest subnormal, one, max finite, inf, and a NaN payload (the
    // abs-bits order ranks NaNs above inf, so counts must include them).
    for thr in [0u32, 1, 0x3F80_0000, 0x7F7F_FFFF, 0x7F80_0000, 0x7FC0_0001] {
        let want = topk::count_abs_ge(&block, thr);
        for level in active_levels() {
            assert_eq!(
                simd::count_abs_ge(level, &block, thr),
                want,
                "count_abs_ge thr={thr:#x} level={level:?}"
            );
        }
    }
}

#[test]
fn nan_blocks_select_k_deterministic_identical_indices() {
    // A block thick with NaNs and infinities: the selection ranks on the
    // *total order of abs bits* (NaN payloads above inf above finites),
    // so every level — and any candidate-prefilter path — must produce
    // the same k indices in the same order, with no float compares to
    // trip on. n = 256 >= the prefilter engagement threshold, so a
    // vector level runs the count_abs_ge thinning pass here.
    let n = 256usize;
    let k = 13usize;
    let block: Vec<f32> = (0..n)
        .map(|i| match i % 5 {
            0 => f32::from_bits(0x7FC0_0000 | ((i as u32 * 7919) & 0x003F_FFFF)), // NaN payloads
            1 => f32::from_bits(0xFFC0_0001), // negative NaN
            2 => f32::INFINITY,
            3 => f32::NEG_INFINITY,
            _ => ((i as f32) - 128.0) / 17.0,
        })
        .collect();
    let mut base_idx = vec![0u16; k];
    let mut base_vals = vec![0f32; k];
    let mut scratch = Vec::new();
    topk_abs_block_with(Level::Scalar, &block, k, &mut base_idx, &mut base_vals, &mut scratch);
    // k distinct indices, deterministically ordered by (abs bits desc,
    // index asc) — NaNs outrank inf, which outranks every finite.
    let mut seen = base_idx.clone();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), k, "selection must return k distinct indices");
    for level in active_levels() {
        let mut idx = vec![0u16; k];
        let mut vals = vec![0f32; k];
        topk_abs_block_with(level, &block, k, &mut idx, &mut vals, &mut scratch);
        assert_eq!(idx, base_idx, "NaN-block selection order level={level:?}");
        assert_eq!(bits32(&vals), bits32(&base_vals), "NaN-block values level={level:?}");
    }
}
