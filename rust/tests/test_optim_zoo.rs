//! The optimizer-zoo gate tier: every first-class optimizer must hold the
//! trio of contracts that make it shippable —
//!
//! 1. **snapshot/restore**: a mid-run `snapshot_state` restored into a
//!    fresh instance continues the trajectory bit-exactly (and kinds
//!    without snapshots say so with `None` / a typed restore error);
//! 2. **accounting**: `state_bytes` equals the measured bytes the bench
//!    lane reports, for every kind in the registry (no hardcoded lists);
//! 3. **structure**: Adam-mini's per-block second moment is exactly the
//!    EMA of the in-block mean squared gradient, and LDAdam's projectors
//!    keep their shape/orthonormality with sane EF-residual bookkeeping.

use microadam::bench;
use microadam::coordinator::config::{optimizer_name, parse_optimizer};
use microadam::coordinator::layout::TensorSpec;
use microadam::optim::adammini::{AdamMini, AdamMiniConfig};
use microadam::optim::ldadam::{LdAdam, LdAdamConfig};
use microadam::optim::{self, Optimizer, OptimizerKind};
use microadam::util::rng::Rng;

fn randvec(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.gen_f32() - 0.5) * 2.0 * s).collect()
}

fn specs(side: usize) -> Vec<TensorSpec> {
    vec![TensorSpec::new("w", &[side, side], 0)]
}

/// The registry kinds that implement the snapshot/restore contract.
const SNAPSHOT_KINDS: [OptimizerKind; 3] =
    [OptimizerKind::MicroAdam, OptimizerKind::LdAdam, OptimizerKind::AdamMini];

// ---------------------------------------------------------------------------
// 1. snapshot / restore
// ---------------------------------------------------------------------------

#[test]
fn mid_run_snapshot_restore_resumes_bit_exactly_for_every_snapshot_kind() {
    let d = 256;
    for kind in SNAPSHOT_KINDS {
        let mut a = optim::build(kind, d, &specs(16), 0.0);
        let mut rng = Rng::seed_from_u64(3);
        let mut xa = randvec(&mut rng, d, 1.0);
        for _ in 0..7 {
            let g = randvec(&mut rng, d, 1.0);
            a.step(&mut xa, &g, 5e-3);
        }
        let snap = a
            .snapshot_state()
            .unwrap_or_else(|| panic!("{kind:?} must support snapshot_state"));
        let mut b = optim::build(kind, d, &specs(16), 0.0);
        b.restore_state(&snap).unwrap();
        assert_eq!(b.t(), a.t(), "{kind:?} resumed step counter");
        let mut xb = xa.clone();
        for s in 0..6 {
            let g = randvec(&mut rng, d, 1.0);
            a.step(&mut xa, &g, 5e-3);
            b.step(&mut xb, &g, 5e-3);
            assert_eq!(xa, xb, "{kind:?} diverged at step {s} after restore");
        }
        assert_eq!(
            a.snapshot_state(),
            b.snapshot_state(),
            "{kind:?} state diverged after restore"
        );
    }
}

#[test]
fn non_snapshot_kinds_return_none_and_reject_foreign_state() {
    // Build a real snapshot to throw at them.
    let d = 128;
    let mut donor = AdamMini::new(d, AdamMiniConfig { block: 64, ..Default::default() });
    let mut rng = Rng::seed_from_u64(9);
    let mut x = randvec(&mut rng, d, 1.0);
    let g = randvec(&mut rng, d, 1.0);
    donor.step(&mut x, &g, 1e-2);
    let snap = donor.snapshot_state().unwrap();

    for &kind in OptimizerKind::all() {
        if SNAPSHOT_KINDS.contains(&kind) {
            continue;
        }
        let mut o = optim::build(kind, d, &specs(8), 0.0);
        assert!(
            o.snapshot_state().is_none(),
            "{kind:?} claims a snapshot it cannot restore through the checkpoint"
        );
        let err = o.restore_state(&snap).unwrap_err().to_string();
        assert!(!err.is_empty(), "{kind:?} restore must be a typed error");
    }
}

#[test]
fn snapshot_kinds_reject_each_others_state() {
    let d = 256;
    for donor_kind in SNAPSHOT_KINDS {
        let mut donor = optim::build(donor_kind, d, &specs(16), 0.0);
        let mut rng = Rng::seed_from_u64(4);
        let mut x = randvec(&mut rng, d, 1.0);
        let g = randvec(&mut rng, d, 1.0);
        donor.step(&mut x, &g, 1e-2);
        let snap = donor.snapshot_state().unwrap();
        for other_kind in SNAPSHOT_KINDS {
            if other_kind == donor_kind {
                continue;
            }
            let mut o = optim::build(other_kind, d, &specs(16), 0.0);
            assert!(
                o.restore_state(&snap).is_err(),
                "{other_kind:?} silently accepted a {donor_kind:?} snapshot"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// 2. accounting: state_bytes vs the bench lane's measured report
// ---------------------------------------------------------------------------

#[test]
fn resident_state_report_enumerates_the_registry_and_matches_state_bytes() {
    let d = 4096;
    let report = bench::resident_state_report(d);
    assert_eq!(
        report.len(),
        OptimizerKind::all().len(),
        "resident_state_report must cover every registered optimizer"
    );
    let side = (d as f64).sqrt() as usize;
    for (i, &kind) in OptimizerKind::all().iter().enumerate() {
        let opt = optim::build(kind, d, &specs(side), 0.0);
        assert_eq!(report[i].0, opt.name(), "row {i} name");
        assert_eq!(report[i].1, opt.state_bytes(), "{kind:?} measured bytes");
        assert_eq!(report[i].2, opt.paper_state_bytes(), "{kind:?} paper bytes");
    }
}

#[test]
fn zoo_paper_accounting_matches_documented_formulas() {
    let d = 4096usize;
    // Adam-mini: 4*(d + ceil(d/B)) bytes — m in f32 plus one v scalar per
    // block; resident == paper (nothing quantized to discount).
    let mini = AdamMini::new(d, AdamMiniConfig::default());
    assert_eq!(mini.state_bytes(), 4 * (d + d.div_ceil(microadam::BLOCK)));
    assert_eq!(mini.paper_state_bytes(), mini.state_bytes());

    // LDAdam at defaults (r=4, cols=64 -> rows=64 per 4096-block): paper
    // accounting is P + m + v (f32) + the 4-bit EF store = 1.25 B/param at
    // this shape; the resident figure adds the Quant4 bucket stats.
    let ld = LdAdam::new(d, LdAdamConfig::default());
    assert_eq!(ld.paper_state_bytes(), 5120);
    assert!(ld.state_bytes() > ld.paper_state_bytes());
}

#[test]
fn registry_and_cli_names_agree() {
    for &kind in OptimizerKind::all() {
        let name = optimizer_name(kind);
        assert_eq!(
            parse_optimizer(name).unwrap(),
            kind,
            "CLI name {name} does not round-trip"
        );
    }
}

// ---------------------------------------------------------------------------
// 3. structural invariants
// ---------------------------------------------------------------------------

#[test]
fn adammini_v_is_the_ema_of_in_block_mean_squared_gradient() {
    // Property: after any trajectory, v[b] is exactly the beta2-EMA of
    // mean(g^2) over block b's *real* element count (the short tail block
    // averages over its own length, not the padded one) — recomputed here
    // independently, compared bitwise.
    let d = 517; // 8 full blocks of 64 + a 5-element tail
    let cfg = AdamMiniConfig { block: 64, ..Default::default() };
    let mut opt = AdamMini::new(d, cfg);
    let nb = opt.n_blocks();
    assert_eq!(nb, 9);
    let mut rng = Rng::seed_from_u64(21);
    let mut x = randvec(&mut rng, d, 1.0);
    let mut expect = vec![0f32; nb];
    for step in 0..9 {
        let g = randvec(&mut rng, d, 1.0);
        opt.step(&mut x, &g, 3e-3);
        let mut off = 0usize;
        for eb in expect.iter_mut() {
            let span = &g[off..(off + cfg.block).min(d)];
            let mut sum = 0f32;
            for &gi in span {
                sum += gi * gi;
            }
            let mean = sum / span.len() as f32;
            *eb = cfg.beta2 * *eb + (1.0 - cfg.beta2) * mean;
            off += span.len();
        }
        assert_eq!(opt.snapshot().v, expect, "v diverged from the EMA at step {step}");
    }
}

#[test]
fn ldadam_projector_shapes_orthonormality_and_ef_bookkeeping() {
    let cfg = LdAdamConfig {
        rank: 2,
        update_every: 2,
        block: 64,
        cols: 8,
        qbucket: 16,
        ..Default::default()
    };
    let d = 1000; // pads to 1024 -> 16 blocks of (8 rows x 8 cols)
    let mut opt = LdAdam::new(d, cfg);
    let geom = opt.geometry();
    assert_eq!((geom.block, geom.cols, geom.rows, geom.rank), (64, 8, 8, 2));
    assert_eq!(geom.n_blocks, 16);

    let mut rng = Rng::seed_from_u64(13);
    let mut x = randvec(&mut rng, d, 1.0);
    for _ in 0..6 {
        let g = randvec(&mut rng, d, 1.0);
        opt.step(&mut x, &g, 5e-3);
    }

    // Projector shape and column orthonormality per block.
    for b in 0..geom.n_blocks {
        let p = opt.projector(b);
        assert_eq!(p.len(), geom.cols * geom.rank, "block {b} projector shape");
        for i in 0..geom.rank {
            for j in 0..geom.rank {
                let dot: f32 = (0..geom.cols)
                    .map(|r| p[r * geom.rank + i] * p[r * geom.rank + j])
                    .sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (dot - want).abs() < 1e-3,
                    "block {b} P^T P[{i}][{j}] = {dot}, want {want}"
                );
            }
        }
    }

    // EF-residual bookkeeping: the quantized residual holds real mass and
    // is mostly outside the tracked subspace (that is what the projector
    // could not represent); both norms must be finite and consistent with
    // the snapshot's buffer geometry.
    assert!(opt.ef_norm() > 0.0, "EF residual is empty after 6 steps");
    let ratio = opt.ef_projection_ratio();
    assert!((0.0..1.0).contains(&ratio), "projection ratio {ratio} out of range");
    let snap = opt.snapshot();
    let d_pad = geom.block * geom.n_blocks;
    assert_eq!(snap.proj.len(), geom.n_blocks * geom.cols * geom.rank);
    assert_eq!(snap.m.len(), geom.n_blocks * geom.rows * geom.rank);
    assert_eq!(snap.v.len(), snap.m.len());
    assert_eq!(snap.ef.len(), d_pad / 2);
    assert_eq!(snap.qlo.len(), d_pad / geom.qbucket);
    assert_eq!(snap.qhi.len(), snap.qlo.len());
}
