//! Topology parity: ring and tree aggregation must be bit-identical to
//! the loopback/star collective, and the partial-aggregate they are built
//! on must not care how payloads are ordered or associated.
//!
//! Two tiers:
//!
//! * property tests on [`GradReducer::accumulate_payload`] /
//!   [`GradReducer::finalize_partial`] — the invariant ring aggregation
//!   silently depends on: folding payloads rank-ascending from a zeroed
//!   accumulator is **bit-exact** against the batch `aggregate_payloads`
//!   kernel (same op order by construction), while *permuting* or
//!   *re-associating* the fold only moves results within a documented
//!   ULP budget (f32 addition is commutative but not associative, so
//!   reassociation is inherently a rounding change, never a value change);
//! * end-to-end runs: ring/tree × dense/topk/eftopk × ranks {2, 4, 8} ×
//!   uds/tcp endpoints reproduce the loopback loss series and final
//!   parameters bit-for-bit.
//!
//! Everything binds `127.0.0.1:0` ephemeral ports or per-test temp socket
//! paths: parallel `cargo test` shards cannot collide.
//!
//! [`GradReducer::accumulate_payload`]: microadam::dist::reducer::GradReducer::accumulate_payload
//! [`GradReducer::finalize_partial`]: microadam::dist::reducer::GradReducer::finalize_partial

use std::path::PathBuf;

use microadam::coordinator::config::TrainConfig;
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::dist::wire::{self, HOP_PREFIX_BYTES};
use microadam::dist::{
    build_reducer, ring_tcp_coordinator, ring_tcp_worker, ring_uds_coordinator, ring_uds_worker,
    tree_tcp_coordinator, tree_tcp_worker, tree_uds_coordinator, tree_uds_worker, DistTrainer,
    ReducerKind, SparseReduceConfig, TcpPending, Topology, Transport, TransportKind, UdsPending,
};
use microadam::exec::ExecPool;
use microadam::optim::OptimizerKind;

const STEPS: u64 = 6;
const KINDS: [ReducerKind; 3] = [ReducerKind::Dense, ReducerKind::TopK, ReducerKind::EfTopK];

/// Permuting or re-associating an n ≤ 8 way f32 sum perturbs each
/// coordinate by at most a few rounding steps; this budget is the
/// documented bound (see `rust/src/dist/README.md` §10). The *fixed*
/// rank-ascending order the ring actually uses is held to 0 ULP.
const REASSOC_ULP_BUDGET: i64 = 8;

// ---------------------------------------------------------------------------
// Property tier: the partial aggregate itself
// ---------------------------------------------------------------------------

/// Monotone integer image of an f32 (both zeros map to 0): ULP distance
/// is the difference of these keys.
fn monotone(x: f32) -> i64 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 { -((b & 0x7fff_ffff) as i64) } else { b as i64 }
}

fn ulp_diff(a: f32, b: f32) -> i64 {
    (monotone(a) - monotone(b)).abs()
}

/// Deterministic per-rank gradients over a mix of scales and signs.
fn gen_grads(d: usize, ranks: usize) -> Vec<Vec<f32>> {
    (0..ranks)
        .map(|r| {
            (0..d)
                .map(|i| {
                    let base = ((i * 37 + r * 101) % 29) as f32 - 14.0;
                    base * 0.07 * if (i + r) % 3 == 0 { 8.0 } else { 1.0 }
                })
                .collect()
        })
        .collect()
}

/// Zero-init + rank-order fold + finalize, over `order`.
fn fold_in_order(
    kind: ReducerKind,
    d: usize,
    ranks: usize,
    payloads: &[Vec<u8>],
    order: &[usize],
) -> Vec<f32> {
    let r = build_reducer(kind, d, ranks, SparseReduceConfig::default());
    let mut acc = vec![0f32; d];
    for &i in order {
        r.accumulate_payload(&payloads[i], &mut acc).unwrap();
    }
    r.finalize_partial(&mut acc);
    acc
}

/// The slab geometries the sweep exercises: aligned, ragged-last-block,
/// prime-sized, and larger-than-one-block dims at each world size.
const GEOMETRIES: [(usize, usize); 4] = [(96, 2), (300, 4), (257, 3), (1024, 8)];

#[test]
fn rank_ascending_fold_matches_batch_aggregate_bitwise() {
    // The exact claim the ring hop chain rests on: zero accumulator +
    // accumulate_payload in rank order + finalize_partial runs the same
    // additions in the same order as the phase-B batch kernel, so the
    // results are bit-identical — for every reducer and geometry.
    let pool = ExecPool::serial();
    for kind in KINDS {
        for &(d, ranks) in &GEOMETRIES {
            let mut reducer = build_reducer(kind, d, ranks, SparseReduceConfig::default());
            let grads = gen_grads(d, ranks);
            let payloads: Vec<Vec<u8>> =
                (0..ranks).map(|r| reducer.compress_payload(r, &grads[r])).collect();

            let mut batch = vec![0f32; d];
            reducer.aggregate_payloads(&payloads, &mut batch, &pool).unwrap();

            let mut loaded = vec![0f32; d];
            for (r, p) in payloads.iter().enumerate() {
                reducer.load_payload(r, p).unwrap();
            }
            reducer.aggregate_loaded(&mut loaded, &pool).unwrap();

            let order: Vec<usize> = (0..ranks).collect();
            let fold = fold_in_order(kind, d, ranks, &payloads, &order);

            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&fold), bits(&batch), "{kind:?} d={d} x{ranks}: fold vs batch");
            assert_eq!(bits(&loaded), bits(&batch), "{kind:?} d={d} x{ranks}: loaded vs batch");
        }
    }
}

#[test]
fn fold_is_permutation_invariant_within_ulp_budget() {
    for kind in KINDS {
        for &(d, ranks) in &GEOMETRIES {
            let mut reducer = build_reducer(kind, d, ranks, SparseReduceConfig::default());
            let grads = gen_grads(d, ranks);
            let payloads: Vec<Vec<u8>> =
                (0..ranks).map(|r| reducer.compress_payload(r, &grads[r])).collect();
            let ascending: Vec<usize> = (0..ranks).collect();
            let reference = fold_in_order(kind, d, ranks, &payloads, &ascending);

            let reversed: Vec<usize> = (0..ranks).rev().collect();
            // stride-5 walk: a true permutation for every world size here
            // (5 is coprime with 2, 3, 4 and 8)
            let strided: Vec<usize> = (0..ranks).map(|i| (i * 5 + 1) % ranks).collect();
            for order in [reversed, strided] {
                let permuted = fold_in_order(kind, d, ranks, &payloads, &order);
                for (i, (&a, &b)) in reference.iter().zip(&permuted).enumerate() {
                    let ulps = ulp_diff(a, b);
                    assert!(
                        ulps <= REASSOC_ULP_BUDGET,
                        "{kind:?} d={d} x{ranks} order {order:?}: coord {i} moved \
                         {ulps} ULPs ({a:e} vs {b:e}), budget {REASSOC_ULP_BUDGET}"
                    );
                }
            }
        }
    }
}

#[test]
fn fold_is_association_invariant_within_ulp_budget() {
    // Re-associating the sum — folding two halves separately and adding
    // the partials — must also stay inside the budget: this is what a
    // deeper reduction tree (or a future segmented ring) would do.
    for kind in KINDS {
        for &(d, ranks) in &GEOMETRIES {
            if ranks < 4 {
                continue; // halves of a 2-rank fold are single payloads
            }
            let mut reducer = build_reducer(kind, d, ranks, SparseReduceConfig::default());
            let grads = gen_grads(d, ranks);
            let payloads: Vec<Vec<u8>> =
                (0..ranks).map(|r| reducer.compress_payload(r, &grads[r])).collect();
            let ascending: Vec<usize> = (0..ranks).collect();
            let reference = fold_in_order(kind, d, ranks, &payloads, &ascending);

            let r = build_reducer(kind, d, ranks, SparseReduceConfig::default());
            let (mut lo, mut hi) = (vec![0f32; d], vec![0f32; d]);
            for i in 0..ranks / 2 {
                r.accumulate_payload(&payloads[i], &mut lo).unwrap();
            }
            for i in ranks / 2..ranks {
                r.accumulate_payload(&payloads[i], &mut hi).unwrap();
            }
            let mut merged: Vec<f32> = lo.iter().zip(&hi).map(|(a, b)| a + b).collect();
            r.finalize_partial(&mut merged);
            for (i, (&a, &b)) in reference.iter().zip(&merged).enumerate() {
                let ulps = ulp_diff(a, b);
                assert!(
                    ulps <= REASSOC_ULP_BUDGET,
                    "{kind:?} d={d} x{ranks}: half-split reassociation moved coord {i} \
                     by {ulps} ULPs ({a:e} vs {b:e})"
                );
            }
        }
    }
}

#[test]
fn hop_payload_roundtrip_is_bit_preserving() {
    // The hop codec carries raw f32 bit patterns: NaN payloads, signed
    // zeros and subnormals must survive the wire unchanged — the fold is
    // arithmetic on *bits the reducers produced*, not on sanitized values.
    let partial = [
        0.0f32,
        -0.0,
        1.5,
        -3.25e-7,
        f32::MIN_POSITIVE / 2.0, // subnormal
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
    ];
    let payload = wire::hop_payload(5, &partial);
    assert_eq!(payload.len(), HOP_PREFIX_BYTES + 4 * partial.len());
    let (fan_in, back) = wire::hop_from_payload(&payload).unwrap();
    assert_eq!(fan_in, 5);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&back), bits(&partial), "hop roundtrip must not touch bit patterns");

    // truncation anywhere is a typed error, never a short vector
    for cut in [0, HOP_PREFIX_BYTES - 1, payload.len() - 1, payload.len() - 3] {
        assert!(
            wire::hop_from_payload(&payload[..cut]).is_err(),
            "hop payload cut to {cut} bytes must be rejected"
        );
    }
}

// ---------------------------------------------------------------------------
// End-to-end tier: ring/tree endpoints vs loopback, bit for bit
// ---------------------------------------------------------------------------

fn cfg(
    ranks: usize,
    reduce: ReducerKind,
    transport: TransportKind,
    topology: Topology,
) -> TrainConfig {
    TrainConfig {
        model: "mlp_tiny".into(),
        optimizer: OptimizerKind::MicroAdam,
        schedule: LrSchedule::Const { lr: 3e-3 },
        steps: STEPS,
        seed: 7,
        log_every: 10_000,
        workers: 1,
        ranks,
        reduce,
        transport,
        topology,
        ..Default::default()
    }
}

/// Loss series (bit patterns) + final params of the loopback reference.
fn run_loopback(ranks: usize, reduce: ReducerKind) -> (Vec<u32>, Vec<f32>) {
    let mut t = DistTrainer::new(cfg(ranks, reduce, TransportKind::Loopback, Topology::Star))
        .unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    t.train(&mut logger).unwrap();
    (logger.history.iter().map(|m| m.loss.to_bits()).collect(), t.params_vec())
}

fn run_endpoint(
    ranks: usize,
    reduce: ReducerKind,
    kind: TransportKind,
    topo: Topology,
    transport: Box<dyn Transport>,
    rank: usize,
) -> (Vec<u32>, Vec<f32>) {
    let mut t = DistTrainer::with_transport(cfg(ranks, reduce, kind, topo), transport, vec![rank])
        .unwrap();
    let mut logger = MetricsLogger::new("").unwrap();
    t.train(&mut logger).unwrap();
    assert_eq!(t.topology(), topo);
    assert!(t.decode_overlap_ms() >= 0.0, "decode overlap is a duration, never negative");
    (logger.history.iter().map(|m| m.loss.to_bits()).collect(), t.params_vec())
}

fn unique_path(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "microadam-topo-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::SeqCst)
    ))
}

/// One ring/tree run: thread-per-rank endpoints over a real socket pair
/// set, returning the coordinator's report plus every worker's params.
fn run_topo(
    kind: TransportKind,
    topo: Topology,
    ranks: usize,
    reduce: ReducerKind,
) -> ((Vec<u32>, Vec<f32>), Vec<Vec<f32>>) {
    match kind {
        TransportKind::Tcp => {
            let pending = TcpPending::bind("127.0.0.1:0", ranks).unwrap();
            let addr = pending.local_addr().unwrap().to_string();
            let workers: Vec<_> = (1..ranks)
                .map(|r| {
                    let addr = addr.clone();
                    std::thread::spawn(move || {
                        let t: Box<dyn Transport> = match topo {
                            Topology::Ring => Box::new(ring_tcp_worker(&addr, r, ranks).unwrap()),
                            Topology::Tree => Box::new(tree_tcp_worker(&addr, r, ranks).unwrap()),
                            Topology::Star => unreachable!("star is covered by test_tcp_parity"),
                        };
                        run_endpoint(ranks, reduce, kind, topo, t, r)
                    })
                })
                .collect();
            let coord_t: Box<dyn Transport> = match topo {
                Topology::Ring => Box::new(ring_tcp_coordinator(pending).unwrap()),
                Topology::Tree => Box::new(tree_tcp_coordinator(pending).unwrap()),
                Topology::Star => unreachable!(),
            };
            let coord = run_endpoint(ranks, reduce, kind, topo, coord_t, 0);
            let wparams =
                workers.into_iter().map(|w| w.join().unwrap().1).collect();
            (coord, wparams)
        }
        TransportKind::Uds => {
            let path = unique_path("rdv");
            let pending = UdsPending::bind(&path, ranks).unwrap();
            let workers: Vec<_> = (1..ranks)
                .map(|r| {
                    let path = path.clone();
                    std::thread::spawn(move || {
                        let t: Box<dyn Transport> = match topo {
                            Topology::Ring => Box::new(ring_uds_worker(&path, r, ranks).unwrap()),
                            Topology::Tree => Box::new(tree_uds_worker(&path, r, ranks).unwrap()),
                            Topology::Star => unreachable!("star is covered by test_tcp_parity"),
                        };
                        run_endpoint(ranks, reduce, kind, topo, t, r)
                    })
                })
                .collect();
            let coord_t: Box<dyn Transport> = match topo {
                Topology::Ring => Box::new(ring_uds_coordinator(pending).unwrap()),
                Topology::Tree => Box::new(tree_uds_coordinator(pending).unwrap()),
                Topology::Star => unreachable!(),
            };
            let coord = run_endpoint(ranks, reduce, kind, topo, coord_t, 0);
            let wparams =
                workers.into_iter().map(|w| w.join().unwrap().1).collect();
            (coord, wparams)
        }
        other => unreachable!("no topology drivers for {other:?}"),
    }
}

/// The acceptance sweep for one (transport, topology) pair: every reducer
/// at ranks 2, 4 and 8 reproduces loopback bit-for-bit on every endpoint.
fn assert_parity(kind: TransportKind, topo: Topology) {
    for ranks in [2usize, 4, 8] {
        for reduce in KINDS {
            let (loop_losses, loop_params) = run_loopback(ranks, reduce);
            assert_eq!(loop_losses.len(), STEPS as usize);
            let ((losses, params), wparams) = run_topo(kind, topo, ranks, reduce);
            assert_eq!(losses, loop_losses, "{kind:?}/{topo:?} {reduce:?} x{ranks} losses");
            assert_eq!(params, loop_params, "{kind:?}/{topo:?} {reduce:?} x{ranks} params");
            for (i, wp) in wparams.iter().enumerate() {
                assert_eq!(
                    *wp,
                    loop_params,
                    "{kind:?}/{topo:?} {reduce:?} x{ranks} worker rank {}",
                    i + 1
                );
            }
        }
    }
}

#[test]
fn tcp_ring_matches_loopback_bitwise() {
    assert_parity(TransportKind::Tcp, Topology::Ring);
}

#[test]
fn tcp_tree_matches_loopback_bitwise() {
    assert_parity(TransportKind::Tcp, Topology::Tree);
}

#[test]
fn uds_ring_matches_loopback_bitwise() {
    assert_parity(TransportKind::Uds, Topology::Ring);
}

#[test]
fn uds_tree_matches_loopback_bitwise() {
    assert_parity(TransportKind::Uds, Topology::Tree);
}
