//! Per-optimizer step-time benchmark (paper Tables 1/2 runtime column
//! analogue at the micro level): every native optimizer at two problem
//! sizes, then the sequential-vs-parallel scaling of the block-sharded
//! fused step engine. criterion is not in the offline crate set; uses the
//! in-repo median-of-runs harness.
//!
//! Run: `cargo bench --bench bench_optimizer_step`
//!
//! Smoke lane (`make bench-smoke`): `MICROADAM_BENCH_SMOKE=1` shrinks the
//! sweep to a few seconds, and `MICROADAM_BENCH_JSON=path` writes a
//! `BENCH_*.json` record (steps/s per engine configuration, measured
//! resident state bytes/param, bf16 window bytes/value, per-rank wire
//! bytes, per-kernel scalar-vs-simd medians, the bytes-vs-loss
//! `"frontier"` rows, and the star/ring/tree `"topology"` sweep) so the
//! perf trajectory is recorded across PRs.

use microadam::bench;

fn main() {
    let smoke = std::env::var("MICROADAM_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);

    println!("== optimizer step micro-benchmark (native backends) ==");
    if smoke {
        bench::bench_optimizer_steps(4096, 5);
    } else {
        bench::bench_optimizer_steps(4096, 21);
        bench::bench_optimizer_steps(262144, 11);
    }

    println!("\n== sequential vs parallel (fused block-sharded engine) ==");
    let d_scale = if smoke { 1 << 18 } else { 1 << 20 };
    let iters = if smoke { 3 } else { 7 };
    let rows = bench::bench_parallel_scaling(d_scale, iters);

    // Per-kernel scalar-vs-simd medians (same math both columns — the
    // simd feature is a codegen knob, so the delta is pure vectorization).
    println!("\n== per-kernel scalar vs simd ==");
    let kernels = bench::bench_kernel_rows(d_scale, if smoke { 3 } else { 7 });

    // Disabled-tracing cost of one traced-capable fused step, as % of the
    // step. The trace-smoke lane (`MICROADAM_TRACE_ASSERT=1`) turns the
    // < 1% acceptance bound into a hard failure.
    println!("\n== disabled-tracing overhead ==");
    let overhead_pct = bench::trace_overhead_pct(d_scale, if smoke { 5 } else { 9 });
    if std::env::var("MICROADAM_TRACE_ASSERT").map(|v| v == "1").unwrap_or(false) {
        assert!(
            overhead_pct < 1.0,
            "disabled tracing costs {overhead_pct:.4}% of a fused step (bound: 1%)"
        );
        println!("trace overhead assert: {overhead_pct:.4}% < 1% OK");
    }

    if let Ok(path) = std::env::var("MICROADAM_BENCH_JSON") {
        if !path.is_empty() {
            // Real-socket probe for the gather/relay overlap record
            // (127.0.0.1 ephemeral port; prints its own >= 0 check).
            println!("\n== tcp gather/relay overlap probe ==");
            let tcp = match bench::run_tcp_probe(20) {
                Ok(p) => {
                    p.print();
                    Some(p)
                }
                Err(e) => {
                    eprintln!("bench smoke: tcp overlap probe failed: {e:#}");
                    None
                }
            };
            // Bytes-vs-loss frontier across the memory-accounting
            // headliners (short runs in the smoke lane; the full curve is
            // bench_e2e's job).
            println!("\n== bytes-vs-loss frontier ==");
            let frontier = match bench::run_frontier(if smoke { 40 } else { 200 }) {
                Ok(rows) => rows,
                Err(e) => {
                    eprintln!("bench smoke: frontier sweep failed: {e:#}");
                    Vec::new()
                }
            };
            // Topology × ranks sweep: what moves through rank 0 on
            // star/ring/tree, and the overlap each endpoint hides.
            println!("\n== topology x ranks probe ==");
            let topology = match bench::run_topology_probe(if smoke { 12 } else { 40 }) {
                Ok(rows) => rows,
                Err(e) => {
                    eprintln!("bench smoke: topology sweep failed: {e:#}");
                    Vec::new()
                }
            };
            let record = bench::smoke_json(
                d_scale,
                &rows,
                &kernels,
                tcp.as_ref(),
                Some(overhead_pct),
                &frontier,
                &topology,
            );
            match std::fs::write(&path, record.to_string()) {
                Ok(()) => println!("\nbench record written to {path}"),
                Err(e) => eprintln!("\nfailed to write {path}: {e}"),
            }
        }
    }

    println!("\nexpectation (paper §3.1-3.2): MicroAdam's step stays within a small factor of");
    println!("dense AdamW despite recomputing statistics from the window (Table 2 runtime),");
    println!("and the fused engine scales near-linearly across blocks until memory-bound —");
    println!("with the persistent pool, multi-worker wins persist down to small d (no");
    println!("per-step thread-spawn tax) and the bf16 window halves AdamStats traffic.");
}
