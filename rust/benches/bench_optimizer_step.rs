//! Per-optimizer step-time benchmark (paper Tables 1/2 runtime column
//! analogue at the micro level): every native optimizer at two problem
//! sizes. criterion is not in the offline crate set; uses the in-repo
//! median-of-runs harness.
//!
//! Run: `cargo bench --bench bench_optimizer_step`

use microadam::bench;

fn main() {
    println!("== optimizer step micro-benchmark (native backends) ==");
    bench::bench_optimizer_steps(4096, 21);
    bench::bench_optimizer_steps(262144, 11);
    println!("\nexpectation (paper §3.1): MicroAdam's step stays within a small factor of");
    println!("dense AdamW despite recomputing statistics from the window (Table 2 runtime).");
}
