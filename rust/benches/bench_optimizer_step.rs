//! Per-optimizer step-time benchmark (paper Tables 1/2 runtime column
//! analogue at the micro level): every native optimizer at two problem
//! sizes, then the sequential-vs-parallel scaling of the block-sharded
//! fused step engine at d = 1M. criterion is not in the offline crate set;
//! uses the in-repo median-of-runs harness.
//!
//! Run: `cargo bench --bench bench_optimizer_step`

use microadam::bench;

fn main() {
    println!("== optimizer step micro-benchmark (native backends) ==");
    bench::bench_optimizer_steps(4096, 21);
    bench::bench_optimizer_steps(262144, 11);

    println!("\n== sequential vs parallel (fused block-sharded engine) ==");
    bench::bench_parallel_scaling(1 << 20, 7);

    println!("\nexpectation (paper §3.1-3.2): MicroAdam's step stays within a small factor of");
    println!("dense AdamW despite recomputing statistics from the window (Table 2 runtime),");
    println!("and the fused engine scales near-linearly across blocks until memory-bound.");
}
