//! Memory-model bench: regenerates the §3.2 / Appendix-D table (exact
//! numbers) and measures *actual allocated* optimizer state for each native
//! optimizer at a 4M-param model, printing theory vs measured.
//!
//! Run: `cargo bench --bench bench_memory`

use microadam::coordinator::layout::TensorSpec;
use microadam::memory;
use microadam::optim::{self, OptimizerKind};

fn main() {
    microadam::bench::run_memory().unwrap();

    let d = 1 << 22;
    let side = 1 << 11;
    let specs = vec![TensorSpec::new("w", &[side, side], 0)];
    println!("\n== measured native state vs paper formula, d = {d} ==");
    println!("{:<14} {:>14} {:>14} {:>8}", "optimizer", "measured B", "paper B", "ratio");
    for kind in [
        OptimizerKind::AdamW,
        OptimizerKind::AdamW8bit,
        OptimizerKind::Sgd,
        OptimizerKind::MicroAdam,
        OptimizerKind::AdaFactor,
        OptimizerKind::Came,
        OptimizerKind::GaLore,
    ] {
        let opt = optim::build(kind, d, &specs, 0.0);
        let paper = match kind {
            OptimizerKind::AdamW => memory::adamw_fp32(d as u64) as usize,
            OptimizerKind::AdamW8bit => memory::adamw_8bit(d as u64) as usize,
            OptimizerKind::Sgd => memory::sgd_momentum_fp32(d as u64) as usize,
            OptimizerKind::MicroAdam => memory::microadam_default(d as u64) as usize,
            _ => opt.paper_state_bytes(),
        };
        println!(
            "{:<14} {:>14} {:>14} {:>8.3}",
            format!("{kind:?}"),
            opt.paper_state_bytes(),
            paper,
            opt.paper_state_bytes() as f64 / paper as f64
        );
    }
    println!("\n(MicroAdam ratio < 1 is padding granularity; formula assumes exact d/100)");
}
