//! End-to-end step benchmark over the AOT path (Table 2 runtime column):
//! fwd/bwd artifact + each optimizer artifact on lm_tiny (and lm_small when
//! present). Skipped without `artifacts/`.
//!
//! Run: `make artifacts && cargo bench --bench bench_e2e`

use microadam::bench::time_it;
use microadam::coordinator::config::{OptBackend, TrainConfig};
use microadam::coordinator::schedule::LrSchedule;
use microadam::coordinator::trainer::Trainer;
use microadam::optim::OptimizerKind;

fn main() {
    std::env::set_var("MICROADAM_QUIET", "1");

    // MICROADAM_TRACE=path turns the whole bench into a trace session:
    // time_it medians land as counter samples and the dist probes record
    // their transport spans; the Chrome trace file is written on exit.
    let trace_path = std::env::var("MICROADAM_TRACE").ok().filter(|p| !p.is_empty());
    let session = trace_path.as_deref().map(microadam::trace::session_to);

    // Measured resident optimizer-state footprints (allocated buffers, not
    // the paper accounting): microadam's bf16 window vs the adamw/adamw8bit
    // baselines, at a Table-2-ish dimension. Artifact-free.
    println!("== resident optimizer-state bytes/param (measured) ==");
    microadam::bench::resident_state_report(1 << 20);

    // Bytes-vs-loss frontier: the same per-optimizer accounting with the
    // loss axis attached — each optimizer trains the native MLP under an
    // identical schedule (ranks = 1 + dense, bit-identical to
    // single-process), longer runs than the smoke lane.
    println!("\n== bytes-vs-loss frontier (native, artifact-free) ==");
    if let Err(e) = microadam::bench::run_frontier(200) {
        println!("bench_e2e: frontier sweep failed: {e:#}");
    }

    // The data-parallel ranks x reducer sweep runs on the native substrate,
    // so it needs no artifacts: measured framed bytes (payload + wire-frame
    // overhead, serialized through dist::wire) vs loss per reducer.
    println!("\n== data-parallel sweep (native, artifact-free, framed bytes) ==");
    if let Err(e) = microadam::bench::run_dist_sweep("runs", 60) {
        println!("bench_e2e: dist sweep failed: {e:#}");
    }

    // Real sockets: framed-byte accounting and the pipelined coordinator's
    // gather/relay overlap measured over an actual 127.0.0.1 TCP exchange.
    println!("\n== tcp transport probe (real sockets, 127.0.0.1 ephemeral port) ==");
    match microadam::bench::run_tcp_probe(60) {
        Ok(p) => p.print(),
        Err(e) => println!("bench_e2e: tcp probe failed: {e:#}"),
    }

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\nbench_e2e: artifacts/ missing — run `make artifacts` for the AOT rows");
        finish_trace(session, trace_path.as_deref());
        return;
    }
    for model in ["lm_tiny", "lm_small"] {
        println!("\n== e2e train step, {model} ==");
        for (opt, backend) in [
            (OptimizerKind::MicroAdam, OptBackend::Aot),
            (OptimizerKind::AdamW, OptBackend::Aot),
            (OptimizerKind::AdamW8bit, OptBackend::Aot),
            (OptimizerKind::MicroAdam, OptBackend::Native),
        ] {
            let cfg = TrainConfig {
                model: model.into(),
                optimizer: opt,
                backend,
                schedule: LrSchedule::Const { lr: 1e-3 },
                steps: 1,
                log_every: 10_000,
                artifacts_dir: "artifacts".into(),
                ..Default::default()
            };
            let Ok(mut trainer) = Trainer::new(cfg) else {
                println!("  (skipping {opt:?}: trainer init failed)");
                continue;
            };
            // warm the executable cache outside the timer
            let _ = trainer.step(1e-3).unwrap();
            let iters = if model == "lm_tiny" { 11 } else { 5 };
            time_it(
                &format!("{model} {opt:?} [{}]", if backend == OptBackend::Aot { "aot" } else { "native" }),
                1,
                iters,
                || {
                    trainer.step(1e-3).unwrap();
                },
            );
        }
    }
    println!("\npaper shape (Table 2 runtime): MicroAdam within ~15% of AdamW wall-clock.");
    finish_trace(session, trace_path.as_deref());
}

fn finish_trace(session: Option<microadam::trace::TraceSession>, path: Option<&str>) {
    if let Some(s) = session {
        match s.finish() {
            Ok(()) => println!("chrome trace written to {}", path.unwrap_or("?")),
            Err(e) => eprintln!("bench_e2e: trace write failed: {e}"),
        }
    }
}
