//! Substrate kernels micro-benchmark: block Top-K, 4-bit quantize /
//! dequantize, dynamic-8bit, AdamStats window accumulation — the pieces of
//! the paper's CUDA §3.1 implementation, timed on this CPU — plus the
//! per-kernel scalar-vs-simd comparison rows that `make bench-smoke`
//! records into `BENCH_*.json`.
//!
//! Run: `cargo bench --bench bench_kernels` (set `MICROADAM_BENCH_SMOKE=1`
//! for the few-second smoke sweep at a smaller dimension).

use microadam::bench::time_it;
use microadam::exec::ExecPool;
use microadam::optim::microadam::{MicroAdam, MicroAdamConfig};
use microadam::optim::Optimizer;
use microadam::quant::{BucketStats, Dynamic8, Quant4};
use microadam::topk::{topk_abs_block, SlidingWindow, WinDtype};
use microadam::util::rng::Rng;

fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gen_f32() - 0.5).collect()
}

fn main() {
    let smoke = std::env::var("MICROADAM_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let mut rng = Rng::seed_from_u64(0);
    let d: usize = if smoke { 1 << 18 } else { 1 << 22 }; // 256K smoke / 4M full
    let iters = if smoke { 3 } else { 9 };
    let iters_slow = if smoke { 3 } else { 5 };
    let block = microadam::BLOCK;
    let kb = microadam::kb_for_block(block, microadam::DENSITY);
    let x = randvec(&mut rng, d);

    println!("== substrate kernels, d = {d} (block {block}, k_b {kb}) ==");

    // block top-k over the whole vector
    let mut idx = vec![0u16; kb];
    let mut vals = vec![0f32; kb];
    let mut scratch = Vec::new();
    time_it("topk_abs_block x all blocks", 1, iters, || {
        for b in 0..d / block {
            topk_abs_block(&x[b * block..(b + 1) * block], kb, &mut idx, &mut vals, &mut scratch);
        }
    });

    // 4-bit EF quantization
    let q = Quant4::new(microadam::QBUCKET);
    let mut packed = vec![0u8; d / 2];
    let mut stats = vec![BucketStats { lo: 0.0, hi: 0.0 }; d / microadam::QBUCKET];
    time_it("quant4 quantize (full EF)", 1, iters, || {
        q.quantize(&x, &mut packed, &mut stats);
    });
    let mut out = vec![0f32; d];
    time_it("quant4 dequantize_add (full EF)", 1, iters, || {
        q.dequantize_add(&packed, &stats, &mut out);
    });

    // dynamic 8-bit (AdamW-8bit state path)
    let d8 = Dynamic8::unsigned();
    let mut codes = vec![0u8; d];
    let mut scales = vec![0f32; d / 256];
    time_it("dynamic8 quantize", 1, iters_slow, || {
        d8.quantize(&x, 256, &mut codes, &mut scales);
    });
    time_it("dynamic8 dequantize", 1, iters_slow, || {
        d8.dequantize(&codes, 256, &scales, &mut out);
    });

    // AdamStats: dense z1/z2 accumulation from a full window, once per
    // storage dtype — the bf16 window halves the value-stream bytes of the
    // engine's hottest loop (f32 row kept as the bandwidth baseline)
    let m = microadam::WINDOW;
    let nb = d / block;
    let mut params = randvec(&mut rng, d);
    for dtype in [WinDtype::F32, WinDtype::Bf16] {
        let mut win = SlidingWindow::with_dtype(m, nb, kb, dtype);
        let mut scratch = Vec::with_capacity(block);
        let blockbuf: Vec<f32> =
            (0..block).map(|j| (((j * 97) % block) as f32 * 0.37).sin()).collect();
        for row in 0..m {
            for b in 0..nb {
                win.select_into(row, b, &blockbuf, &mut scratch);
            }
            win.commit_row();
        }
        let w1 = win.folded_weights(m as u64, 0.9);
        let w2 = win.folded_weights(m as u64, 0.999);
        let mut z1 = vec![0f32; block];
        let mut z2 = vec![0f32; block];
        time_it(&format!("adamstats + update (full window, {dtype:?} vals)"), 1, iters, || {
            for b in 0..nb {
                z1.fill(0.0);
                z2.fill(0.0);
                for i in 0..m {
                    win.accumulate_stats(i, b, w1[i], w2[i], &mut z1, &mut z2);
                }
                let base = b * block;
                for j in 0..block {
                    params[base + j] -= 1e-3 * z1[j] / (1e-8 + z2[j].sqrt());
                }
            }
        });
    }
    std::hint::black_box(&params);
    std::hint::black_box(&out);

    // the whole step: 4-pass reference sweep vs the fused single pass per
    // block, sequential and sharded (the sum of the kernel rows above is
    // roughly what the reference pays; the fused pass overlaps them in
    // cache)
    println!("\n== fused step engine vs 4-pass reference, d = {d} ==");
    let grads = randvec(&mut rng, d);
    let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
    let mut p = randvec(&mut rng, d);
    let warm = microadam::WINDOW + 1;
    let t_ref = time_it("microadam step_reference (4 sweeps)", warm, iters_slow, || {
        opt.step_reference(&mut p, &grads, 1e-3)
    });
    let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
    let mut p = randvec(&mut rng, d);
    let t_fused = time_it("microadam fused step (1 worker)", warm, iters_slow, || {
        opt.step(&mut p, &grads, 1e-3)
    });
    let pool = ExecPool::auto();
    let mut opt = MicroAdam::new(d, MicroAdamConfig::default());
    let mut p = randvec(&mut rng, d);
    let t_par = time_it(
        &format!("microadam fused step ({} workers)", pool.workers()),
        warm,
        iters_slow,
        || opt.step_sharded(&mut p, &grads, 1e-3, &pool),
    );
    println!(
        "fusion gain {:.2}x, parallel gain {:.2}x (total {:.2}x)",
        t_ref / t_fused,
        t_fused / t_par,
        t_ref / t_par
    );

    // Per-kernel scalar vs simd: every dispatched kernel timed at
    // Level::Scalar and at the host's detected vector level (identical
    // math — the columns differ only in codegen). These are the rows
    // `make bench-smoke` records into BENCH_*.json.
    println!("\n== per-kernel scalar vs simd ==");
    microadam::bench::bench_kernel_rows(d, iters_slow);
}
