//! Scheduler-aware drop-ins for `std::sync` types.
//!
//! Same shapes as `std` (and loom): `lock()` returns a `LockResult`,
//! guards poison on panic, `Condvar::wait` consumes and returns the
//! guard. `Arc` needs no scheduling semantics, so the std type is
//! re-exported unchanged.

use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, PoisonError, TryLockError};

pub use std::sync::Arc;

use crate::rt;

pub mod atomic {
    //! Scheduler-aware atomics. Every access is a scheduling point and
    //! executes `SeqCst` regardless of the ordering the caller asked
    //! for — minloom explores sequentially-consistent interleavings
    //! only (see the crate docs).

    pub use std::sync::atomic::Ordering;
    use std::sync::atomic::Ordering::SeqCst;

    use crate::rt;

    #[derive(Debug, Default)]
    pub struct AtomicUsize {
        v: std::sync::atomic::AtomicUsize,
    }

    impl AtomicUsize {
        pub fn new(v: usize) -> Self {
            Self { v: std::sync::atomic::AtomicUsize::new(v) }
        }

        pub fn load(&self, _order: Ordering) -> usize {
            rt::sched_point();
            self.v.load(SeqCst)
        }

        pub fn store(&self, val: usize, _order: Ordering) {
            rt::sched_point();
            self.v.store(val, SeqCst);
        }

        pub fn fetch_add(&self, val: usize, _order: Ordering) -> usize {
            rt::sched_point();
            self.v.fetch_add(val, SeqCst)
        }

        pub fn fetch_sub(&self, val: usize, _order: Ordering) -> usize {
            rt::sched_point();
            self.v.fetch_sub(val, SeqCst)
        }

        pub fn swap(&self, val: usize, _order: Ordering) -> usize {
            rt::sched_point();
            self.v.swap(val, SeqCst)
        }
    }

    #[derive(Debug, Default)]
    pub struct AtomicBool {
        v: std::sync::atomic::AtomicBool,
    }

    impl AtomicBool {
        pub fn new(v: bool) -> Self {
            Self { v: std::sync::atomic::AtomicBool::new(v) }
        }

        pub fn load(&self, _order: Ordering) -> bool {
            rt::sched_point();
            self.v.load(SeqCst)
        }

        pub fn store(&self, val: bool, _order: Ordering) {
            rt::sched_point();
            self.v.store(val, SeqCst);
        }

        pub fn swap(&self, val: bool, _order: Ordering) -> bool {
            rt::sched_point();
            self.v.swap(val, SeqCst)
        }
    }
}

/// Cooperative mutex: contention and poisoning are modelled by the
/// scheduler; the inner `std` mutex only stores the data and is, by
/// construction, never contended.
#[derive(Debug)]
pub struct Mutex<T> {
    id: usize,
    data: std::sync::Mutex<T>,
}

pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
    /// Whether dropping this guard releases the scheduler-side lock
    /// (false while a `Condvar::wait` hand-off owns the release).
    rt_armed: bool,
}

impl<T> Mutex<T> {
    pub fn new(data: T) -> Self {
        Self { id: rt::register_mutex(), data: std::sync::Mutex::new(data) }
    }

    fn data_guard(&self) -> std::sync::MutexGuard<'_, T> {
        match self.data.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => {
                unreachable!("minloom scheduler granted a contended data mutex")
            }
        }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let poisoned = rt::mutex_lock(self.id);
        let guard = MutexGuard { lock: self, inner: Some(self.data_guard()), rt_armed: true };
        if poisoned {
            Err(PoisonError::new(guard))
        } else {
            Ok(guard)
        }
    }
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard holds the data lock")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard holds the data lock")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.inner.take());
        if self.rt_armed {
            rt::mutex_unlock(self.lock.id);
        }
    }
}

/// Cooperative condition variable. Wakeups are FIFO and never spurious
/// (a deliberate narrowing: it keeps the schedule tree small, and every
/// call site in this repo re-checks its predicate in a loop anyway).
#[derive(Debug)]
pub struct Condvar {
    id: usize,
}

impl Condvar {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self { id: rt::register_condvar() }
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let lock = guard.lock;
        drop(guard.inner.take());
        guard.rt_armed = false; // the wait hand-off releases the rt lock
        drop(guard);
        let poisoned = rt::condvar_wait(self.id, lock.id);
        let guard = MutexGuard { lock, inner: Some(lock.data_guard()), rt_armed: true };
        if poisoned {
            Err(PoisonError::new(guard))
        } else {
            Ok(guard)
        }
    }

    pub fn notify_one(&self) {
        rt::condvar_notify(self.id, false);
    }

    pub fn notify_all(&self) {
        rt::condvar_notify(self.id, true);
    }
}
