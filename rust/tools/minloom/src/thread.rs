//! Scheduler-aware replacements for `std::thread::{spawn, yield_now}`.

use std::sync::{Arc, Mutex};

use crate::rt;

/// Handle to a model thread. `join` parks the caller until the target
/// finishes and returns the closure's result (or its panic payload,
/// matching `std::thread::JoinHandle`).
pub struct JoinHandle<T> {
    tid: usize,
    slot: Arc<Mutex<Option<std::thread::Result<T>>>>,
}

/// Spawn a model thread under the scheduler.
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let slot = Arc::new(Mutex::new(None));
    let out = Arc::clone(&slot);
    let tid = rt::spawn_thread(Box::new(move || {
        // A panic in `f` unwinds past this closure into the runtime,
        // which records it as a model failure; the result slot then
        // simply stays empty (nobody joins a failed execution).
        let value = f();
        *out.lock().unwrap_or_else(|e| e.into_inner()) = Some(Ok(value));
    }));
    JoinHandle { tid, slot }
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        rt::join_thread(self.tid);
        self.slot
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .expect("joined thread stored its result")
    }
}

/// Park the calling thread until another model thread makes progress.
/// This is the cooperative analogue of a spin-loop hint: a loop that
/// yields while polling cannot explode the schedule tree, because the
/// yielding thread is only rescheduled after the state it polls had a
/// chance to change.
pub fn yield_now() {
    rt::yield_now();
}
