//! minloom — a dependency-free, loom-flavoured model checker for the
//! repo's hand-rolled concurrency.
//!
//! The crate mirrors the subset of [loom](https://docs.rs/loom)'s API
//! that `microadam`'s `cfg(loom)` sync shims need — `model`,
//! `thread::{spawn, yield_now, JoinHandle}`, `sync::{Mutex, Condvar}`,
//! `sync::atomic::{AtomicBool, AtomicUsize}` — so the production code
//! compiles unchanged against either checker; `rust/Cargo.toml` maps
//! the `loom` dependency name onto this crate as a path dependency,
//! which keeps `cargo` resolution fully offline (the workspace's
//! no-new-deps rule applies to its analysis tools too). Swapping in
//! the real loom is a one-line manifest change.
//!
//! # What it checks
//!
//! [`model`] runs a closure repeatedly under a cooperative scheduler
//! that owns every interleaving decision. Each synchronization
//! operation (mutex lock/unlock, condvar wait/notify, atomic access,
//! spawn, join, yield) is a *scheduling point*; the explorer performs a
//! depth-first search over the schedule tree:
//!
//! * **all non-preemptive schedules** — the running thread continues
//!   until it blocks or finishes, and every choice of successor at each
//!   blocking point is explored exhaustively; plus
//! * **all schedules with at most `MINLOOM_PREEMPTIONS` forced context
//!   switches** (default 2) injected at arbitrary scheduling points —
//!   the CHESS result: most real concurrency bugs manifest within two
//!   preemptions.
//!
//! Executions are replayed from recorded decision prefixes, so the
//! model closure must be deterministic modulo scheduling (no wall-clock
//! branching, no RNG). A deadlock (no thread can run), a livelock (the
//! per-execution step bound trips), or a panic escaping any model
//! thread fails the model with the offending schedule.
//!
//! # What it does not check
//!
//! Exploration is **sequentially consistent**: every atomic access is
//! executed `SeqCst` whatever ordering the code requested, so bugs that
//! require weak-memory reorderings are out of scope (the real loom
//! models the C11 memory model and would catch those). Exploration is
//! also truncated — with a printed notice, never silently — at
//! `MINLOOM_MAX_EXECUTIONS` schedules (default 20 000).

mod rt;
pub mod sync;
pub mod thread;

pub use rt::model;

#[cfg(test)]
mod tests {
    use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use crate::sync::{Arc, Condvar, Mutex};
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn finds_the_lost_update() {
        // Load-then-store on two threads loses an increment under the
        // right interleaving; the explorer must find the schedule where
        // both threads read 0 and the final value is 1.
        let r = catch_unwind(AssertUnwindSafe(|| {
            crate::model(|| {
                let a = Arc::new(AtomicUsize::new(0));
                let b = a.clone();
                let t = crate::thread::spawn(move || {
                    let v = b.load(Ordering::SeqCst);
                    b.store(v + 1, Ordering::SeqCst);
                });
                let v = a.load(Ordering::SeqCst);
                a.store(v + 1, Ordering::SeqCst);
                t.join().unwrap();
                assert_eq!(a.load(Ordering::SeqCst), 2, "lost update");
            });
        }));
        assert!(r.is_err(), "the racy increment must be caught");
    }

    #[test]
    fn passes_the_atomic_update() {
        crate::model(|| {
            let a = Arc::new(AtomicUsize::new(0));
            let b = a.clone();
            let t = crate::thread::spawn(move || {
                b.fetch_add(1, Ordering::SeqCst);
            });
            a.fetch_add(1, Ordering::SeqCst);
            t.join().unwrap();
            assert_eq!(a.load(Ordering::SeqCst), 2);
        });
    }

    #[test]
    fn detects_lock_order_deadlock() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            crate::model(|| {
                let ab = Arc::new((Mutex::new(0u32), Mutex::new(0u32)));
                let ba = ab.clone();
                let t = crate::thread::spawn(move || {
                    let _a = ba.0.lock().unwrap();
                    let _b = ba.1.lock().unwrap();
                });
                let _b = ab.1.lock().unwrap();
                let _a = ab.0.lock().unwrap();
                drop((_a, _b));
                t.join().unwrap();
            });
        }));
        let msg = r.expect_err("AB-BA locking must be caught");
        let msg = msg
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("deadlock"), "diagnostic names the deadlock: {msg}");
    }

    #[test]
    fn condvar_handshake_completes() {
        crate::model(|| {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let tx = pair.clone();
            let t = crate::thread::spawn(move || {
                let mut flag = tx.0.lock().unwrap();
                *flag = true;
                drop(flag);
                tx.1.notify_one();
            });
            let mut flag = pair.0.lock().unwrap();
            while !*flag {
                flag = pair.1.wait(flag).unwrap();
            }
            drop(flag);
            t.join().unwrap();
        });
    }

    #[test]
    fn poisoned_mutex_reports_and_recovers() {
        crate::model(|| {
            let m = Mutex::new(7u32);
            let r = catch_unwind(AssertUnwindSafe(|| {
                let _g = m.lock().unwrap();
                panic!("poison it");
            }));
            assert!(r.is_err());
            let v = *m.lock().unwrap_or_else(|e| e.into_inner());
            assert_eq!(v, 7);
        });
    }

    #[test]
    fn yield_spin_loop_terminates() {
        crate::model(|| {
            let flag = Arc::new(AtomicBool::new(false));
            let setter = flag.clone();
            let t = crate::thread::spawn(move || {
                setter.store(true, Ordering::SeqCst);
            });
            // The yield parks this thread until the other makes
            // progress, so the spin cannot explode the search.
            while !flag.load(Ordering::SeqCst) {
                crate::thread::yield_now();
            }
            t.join().unwrap();
        });
    }
}
