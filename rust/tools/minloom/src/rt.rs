//! The cooperative scheduler and DFS schedule explorer.
//!
//! One [`Runtime`] is built per execution. Model threads are real OS
//! threads, but exactly one is ever granted the right to run: at every
//! scheduling point the running thread parks itself and hands control
//! to the scheduler (the `model()` caller's thread), which either
//! replays the recorded decision prefix or extends it with a default
//! choice, logging the untried alternatives for later backtracking.

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

pub(crate) type Tid = usize;

/// Silent-unwind payload used to tear threads down once the scheduler
/// has recorded a failure; `resume_unwind` skips the panic hook, so the
/// teardown does not spray spurious backtraces over the real report.
pub(crate) struct AbortExecution;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Status {
    Runnable,
    /// Parked by `yield_now` until another thread makes progress.
    Yielded,
    BlockedMutex(usize),
    BlockedCondvar(usize),
    BlockedJoin(Tid),
    Finished,
}

#[derive(Default)]
struct MutexState {
    owner: Option<Tid>,
    poisoned: bool,
}

#[derive(Default)]
struct CvState {
    /// `(waiter, mutex)` pairs: which thread is parked and which mutex
    /// it must re-acquire once notified.
    waiters: Vec<(Tid, usize)>,
}

struct RtState {
    running: Option<Tid>,
    threads: Vec<Status>,
    mutexes: Vec<MutexState>,
    condvars: Vec<CvState>,
    /// Thread that completed the most recent step (continuation
    /// candidate for preemption accounting).
    last: Option<Tid>,
    steps: usize,
    failure: Option<String>,
}

pub(crate) struct Runtime {
    state: Mutex<RtState>,
    cv: Condvar,
    max_steps: usize,
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Runtime>, Tid)>> = const { RefCell::new(None) };
}

fn with_rt<R>(f: impl FnOnce(&Arc<Runtime>, Tid) -> R) -> R {
    CURRENT.with(|c| {
        let borrow = c.borrow();
        let (rt, tid) = borrow
            .as_ref()
            .expect("minloom sync primitives may only be used inside minloom::model");
        f(rt, *tid)
    })
}

fn abort_unwind() -> ! {
    std::panic::resume_unwind(Box::new(AbortExecution));
}

impl Runtime {
    fn new(max_steps: usize) -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(RtState {
                running: None,
                threads: Vec::new(),
                mutexes: Vec::new(),
                condvars: Vec::new(),
                last: None,
                steps: 0,
                failure: None,
            }),
            cv: Condvar::new(),
            max_steps,
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RtState> {
        // The runtime's own mutex is only poisoned if minloom itself
        // has a bug mid-panic; recover so the diagnostic still surfaces.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Park the calling model thread with `status` (after applying
    /// `pre` under the state lock) and block until the scheduler grants
    /// it the next step. The heart of every scheduling point.
    fn transition(self: &Arc<Self>, me: Tid, status: Status, pre: impl FnOnce(&mut RtState)) {
        let mut st = self.lock();
        pre(&mut st);
        st.steps += 1;
        if st.steps > self.max_steps && st.failure.is_none() {
            st.failure = Some(format!(
                "per-execution step bound {} exceeded — livelock, or a model too big \
                 for exhaustive exploration",
                self.max_steps
            ));
        }
        st.threads[me] = status;
        // Progress by this thread unparks everyone who yielded to wait
        // for it.
        for t in 0..st.threads.len() {
            if t != me && st.threads[t] == Status::Yielded {
                st.threads[t] = Status::Runnable;
            }
        }
        st.last = Some(me);
        st.running = None;
        self.cv.notify_all();
        loop {
            if st.running == Some(me) {
                return;
            }
            if st.failure.is_some() {
                drop(st);
                if std::thread::panicking() {
                    // Already unwinding (e.g. a guard drop): let the
                    // existing unwind continue instead of double-panicking.
                    return;
                }
                abort_unwind();
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// First park of a freshly spawned thread: wait to be granted
    /// without counting a step. Returns false if the execution was
    /// already abandoned.
    fn wait_first_grant(self: &Arc<Self>, me: Tid) -> bool {
        let mut st = self.lock();
        loop {
            if st.running == Some(me) {
                return true;
            }
            if st.failure.is_some() {
                return false;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn record_failure(self: &Arc<Self>, msg: String) {
        let mut st = self.lock();
        if st.failure.is_none() {
            st.failure = Some(msg);
        }
        st.running = None;
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------
// Thread-facing operations (called from sync/thread modules via TLS)
// ---------------------------------------------------------------------

/// A plain scheduling point (atomic access, explicit interleave).
pub(crate) fn sched_point() {
    with_rt(|rt, me| rt.transition(me, Status::Runnable, |_| {}));
}

/// Park until another thread makes progress.
pub(crate) fn yield_now() {
    with_rt(|rt, me| rt.transition(me, Status::Yielded, |_| {}));
}

pub(crate) fn register_mutex() -> usize {
    with_rt(|rt, _| {
        let mut st = rt.lock();
        st.mutexes.push(MutexState::default());
        st.mutexes.len() - 1
    })
}

pub(crate) fn register_condvar() -> usize {
    with_rt(|rt, _| {
        let mut st = rt.lock();
        st.condvars.push(CvState::default());
        st.condvars.len() - 1
    })
}

/// Cooperative mutex acquire: an interleaving point, then either an
/// immediate grab or a block until the scheduler hands over ownership.
/// Returns the poison flag.
pub(crate) fn mutex_lock(id: usize) -> bool {
    with_rt(|rt, me| {
        rt.transition(me, Status::Runnable, |_| {});
        let contended = {
            let mut st = rt.lock();
            if st.mutexes[id].owner.is_none() {
                st.mutexes[id].owner = Some(me);
                false
            } else {
                true
            }
        };
        if contended {
            // The scheduler assigns ownership as part of the grant.
            rt.transition(me, Status::BlockedMutex(id), |_| {});
        }
        rt.lock().mutexes[id].poisoned
    })
}

pub(crate) fn mutex_unlock(id: usize) {
    with_rt(|rt, me| {
        rt.transition(me, Status::Runnable, |st| {
            st.mutexes[id].owner = None;
            if std::thread::panicking() {
                st.mutexes[id].poisoned = true;
            }
        });
    });
}

pub(crate) fn mutex_poisoned(id: usize) -> bool {
    with_rt(|rt, _| rt.lock().mutexes[id].poisoned)
}

/// Atomically enqueue on the condvar and release the mutex, park until
/// notified, then re-acquire the mutex (the scheduler grants ownership
/// with the wakeup). Returns the mutex poison flag.
pub(crate) fn condvar_wait(cv: usize, mutex: usize) -> bool {
    with_rt(|rt, me| {
        rt.transition(me, Status::BlockedCondvar(cv), |st| {
            st.condvars[cv].waiters.push((me, mutex));
            st.mutexes[mutex].owner = None;
        });
        // Granted: the scheduler moved us to BlockedMutex on notify and
        // set ownership before waking us.
        rt.lock().mutexes[mutex].poisoned
    })
}

pub(crate) fn condvar_notify(cv: usize, all: bool) {
    with_rt(|rt, me| {
        rt.transition(me, Status::Runnable, |st| {
            let n = if all { st.condvars[cv].waiters.len() } else { 1 };
            for _ in 0..n {
                if st.condvars[cv].waiters.is_empty() {
                    break;
                }
                let (t, m) = st.condvars[cv].waiters.remove(0);
                st.threads[t] = Status::BlockedMutex(m);
            }
        });
    });
}

/// Register and launch a model thread running `body`; `body` runs on a
/// real OS thread gated by the scheduler and must store its own result
/// before returning. Returns the new thread's id.
pub(crate) fn spawn_thread(body: Box<dyn FnOnce() + Send>) -> Tid {
    with_rt(|rt, me| {
        let tid = {
            let mut st = rt.lock();
            st.threads.push(Status::Runnable);
            st.threads.len() - 1
        };
        let rt2 = Arc::clone(rt);
        std::thread::Builder::new()
            .name(format!("minloom-{tid}"))
            .spawn(move || run_model_thread(rt2, tid, body))
            .expect("spawn minloom model thread");
        // The spawn itself is an interleaving point: the child may run
        // before the parent's next instruction.
        rt.transition(me, Status::Runnable, |_| {});
        tid
    })
}

/// Block until `target` finishes.
pub(crate) fn join_thread(target: Tid) {
    with_rt(|rt, me| {
        rt.transition(me, Status::BlockedJoin(target), |_| {});
    });
}

fn run_model_thread(rt: Arc<Runtime>, tid: Tid, body: Box<dyn FnOnce() + Send>) {
    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&rt), tid)));
    if !rt.wait_first_grant(tid) {
        return;
    }
    let result = catch_unwind(AssertUnwindSafe(body));
    if let Err(payload) = result {
        if payload.downcast_ref::<AbortExecution>().is_none() {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            rt.record_failure(format!("thread {tid} panicked: {msg}"));
        }
    }
    let mut st = rt.lock();
    st.threads[tid] = Status::Finished;
    for t in 0..st.threads.len() {
        if t != tid && st.threads[t] == Status::Yielded {
            st.threads[t] = Status::Runnable;
        }
    }
    st.last = Some(tid);
    st.running = None;
    rt.cv.notify_all();
}

// ---------------------------------------------------------------------
// The explorer
// ---------------------------------------------------------------------

/// One recorded scheduling decision: which thread was granted and which
/// grantable alternatives remain untried. (The continuation thread is
/// recomputed during replay — the model is deterministic under a fixed
/// schedule, so it always matches what extension saw.)
#[derive(Debug)]
struct Decision {
    chosen: Tid,
    untried: Vec<Tid>,
}

fn grantable(st: &RtState, t: Tid) -> bool {
    match st.threads[t] {
        Status::Runnable => true,
        Status::BlockedMutex(m) => st.mutexes[m].owner.is_none(),
        Status::BlockedJoin(t2) => st.threads[t2] == Status::Finished,
        Status::Yielded | Status::BlockedCondvar(_) | Status::Finished => false,
    }
}

fn grant(st: &mut RtState, t: Tid) {
    if let Status::BlockedMutex(m) = st.threads[t] {
        st.mutexes[m].owner = Some(t);
    }
    st.threads[t] = Status::Runnable;
    st.running = Some(t);
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run one full execution following (and extending) `schedule`.
fn run_once(
    f: Arc<dyn Fn() + Send + Sync>,
    schedule: &mut Vec<Decision>,
    preemption_bound: usize,
    max_steps: usize,
) -> Result<(), String> {
    let rt = Runtime::new(max_steps);
    {
        let mut st = rt.lock();
        st.threads.push(Status::Runnable); // tid 0: the model main thread
    }
    {
        let rt2 = Arc::clone(&rt);
        std::thread::Builder::new()
            .name("minloom-0".to_string())
            .spawn(move || run_model_thread(rt2, 0, Box::new(move || f())))
            .expect("spawn minloom main thread");
    }

    let mut cursor = 0usize;
    let mut preemptions = 0usize;
    loop {
        let mut st = rt.lock();
        while st.running.is_some() && st.failure.is_none() {
            st = rt.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if let Some(msg) = st.failure.clone() {
            return Err(msg);
        }
        if st.threads.iter().all(|s| *s == Status::Finished) {
            return Ok(());
        }
        let mut cands: Vec<Tid> =
            (0..st.threads.len()).filter(|&t| grantable(&st, t)).collect();
        if cands.is_empty() {
            // All-yielded means every thread is waiting for someone
            // else's progress: unpark the lot and let the step bound
            // catch true livelocks. Anything else is a deadlock.
            let yielded: Vec<Tid> = (0..st.threads.len())
                .filter(|&t| st.threads[t] == Status::Yielded)
                .collect();
            if yielded.is_empty() {
                let detail: Vec<String> = st
                    .threads
                    .iter()
                    .enumerate()
                    .map(|(t, s)| format!("thread {t}: {s:?}"))
                    .collect();
                return Err(format!("deadlock — no thread can run ({})", detail.join("; ")));
            }
            for t in yielded {
                st.threads[t] = Status::Runnable;
                cands.push(t);
            }
        }
        let cont = st.last.filter(|t| cands.contains(t));
        let chosen = if cursor < schedule.len() {
            let d = &schedule[cursor];
            if !cands.contains(&d.chosen) {
                return Err(format!(
                    "non-deterministic model: replayed choice {} is not grantable \
                     at step {cursor}",
                    d.chosen
                ));
            }
            d.chosen
        } else {
            let chosen = cont.unwrap_or_else(|| cands[0]);
            let untried: Vec<Tid> = match cont {
                // Alternatives to a continuation are preemptions: only
                // explorable while the budget lasts.
                Some(c) if preemptions < preemption_bound => {
                    cands.iter().copied().filter(|&t| t != c).collect()
                }
                Some(_) => Vec::new(),
                // Forced switch: every successor is explored.
                None => cands.iter().copied().filter(|&t| t != chosen).collect(),
            };
            schedule.push(Decision { chosen, untried });
            chosen
        };
        if let Some(c) = cont {
            if chosen != c {
                preemptions += 1;
            }
        }
        cursor += 1;
        grant(&mut st, chosen);
        rt.cv.notify_all();
    }
}

/// Exhaustively model-check `f` (see the crate docs for the exact
/// guarantee). Panics, with the failing schedule, on the first
/// execution that deadlocks, livelocks, or panics.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
    let preemption_bound = env_usize("MINLOOM_PREEMPTIONS", 2);
    let max_executions = env_usize("MINLOOM_MAX_EXECUTIONS", 20_000);
    let max_steps = env_usize("MINLOOM_MAX_STEPS", 100_000);
    let mut schedule: Vec<Decision> = Vec::new();
    let mut executions = 0usize;
    loop {
        executions += 1;
        if let Err(msg) = run_once(Arc::clone(&f), &mut schedule, preemption_bound, max_steps) {
            let trace: Vec<Tid> = schedule.iter().map(|d| d.chosen).collect();
            panic!(
                "minloom: model failed on execution {executions}: {msg}\nschedule: {trace:?}"
            );
        }
        // Backtrack to the deepest decision with an untried branch.
        while matches!(schedule.last(), Some(d) if d.untried.is_empty()) {
            schedule.pop();
        }
        match schedule.last_mut() {
            None => break, // tree exhausted
            Some(d) => {
                let next = d.untried.pop().expect("non-empty by the loop above");
                d.chosen = next;
            }
        }
        if executions >= max_executions {
            eprintln!(
                "minloom: exploration truncated at {executions} executions \
                 (raise MINLOOM_MAX_EXECUTIONS for a deeper search)"
            );
            return;
        }
    }
}
