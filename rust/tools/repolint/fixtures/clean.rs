//@ path: rust/src/dist/transport.rs
//@ expect: clean
// Control fixture: exercises every rule's *passing* form — documented
// unsafe, a justified allowlisted expect, widening-only accounting
// casts, and rule keywords inside string literals (which the lexer
// must ignore). Never compiled — scanned as text only.

pub fn good(xs: &[u32]) -> u32 {
    let banner = "unsafe .unwrap() panic! as u8"; // only prose, in a string
    debug_assert!(!xs.is_empty(), "{banner}");
    // SAFETY: the debug_assert above pins xs non-empty; index 0 is in
    // bounds for the lifetime of the borrow.
    let head = unsafe { *xs.as_ptr() };
    // repolint: allow(no-panic): non-empty pinned by the debug_assert above.
    let tail = xs.last().expect("non-empty");
    head + tail
}

pub fn state_bytes(slots: usize) -> usize {
    let wide = slots as u64;
    (wide * 4) as usize
}
