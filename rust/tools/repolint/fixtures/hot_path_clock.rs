//@ path: rust/src/exec/pool.rs
//@ expect: hot-path-clock
// Seeded violation: an unconditional wall-clock read inside a step-engine
// inner loop. Timing in exec::/optim:: must go through the gated
// `trace::` layer. Never compiled — scanned as text only.

pub fn dispatch(n: usize) -> f64 {
    let mut total = 0.0;
    for _ in 0..n {
        let t0 = std::time::Instant::now();
        work();
        total += t0.elapsed().as_secs_f64();
    }
    total
}

fn work() {}
