//! Drifted-topology fixture: a miniature wire.rs whose hop constants
//! disagree with the README sitting next to it (the code kept the flag
//! at bit 1 / value 2 and a 4-byte prefix; the document claims bit 2 /
//! value 4 and an 8-byte prefix). Never compiled — scanned as text only.

pub const FLAG_HELLO: u8 = 1;
pub const FLAG_HOP: u8 = 2;
pub const HOP_PREFIX_BYTES: usize = 4;
