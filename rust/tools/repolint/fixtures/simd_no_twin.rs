//@ path: rust/src/simd/gather.rs
//@ expect: simd-twin
// Seeded violation: a feature-gated vector kernel whose docs never name
// the always-compiled scalar twin that serves as its bit-exactness
// oracle. Never compiled — scanned as text only.

#[cfg(feature = "simd")]
pub fn gather_sum(xs: &[f32]) -> f32 {
    let mut acc = 0.0;
    for &x in xs {
        acc += x;
    }
    acc
}
