//@ path: rust/src/exec/fixture.rs
//@ expect: unsafe-safety
// Seeded violation: an `unsafe` block whose safety invariant is never
// written down in the required form. Never compiled — scanned as text
// only. (The filler below keeps this header outside the rule's
// five-line lookback window.)

pub fn first(xs: &[u32]) -> u32 {
    let _ = xs.len();
    let _ = xs.is_empty();
    // The pointer is in bounds, honest!  (Not the required comment.)
    unsafe { *xs.as_ptr() }
}
