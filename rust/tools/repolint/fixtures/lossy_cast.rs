//@ path: rust/src/optim/fixture.rs
//@ expect: lossy-cast
// Seeded violation: a truncating cast inside a bytes-accounting
// function. Never compiled — scanned as text only.

impl Accounting {
    pub fn state_bytes(&self) -> usize {
        (self.slots * self.width) as u32 as usize
    }

    pub fn other(&self) -> usize {
        // Outside an accounting fn: casts are the optimizer's business.
        self.slots as u32 as usize
    }
}
