//! Drifted-spec fixture: a miniature wire.rs whose constants disagree
//! with the README sitting next to it (the code says version 2, the
//! document still says 1). Never compiled — scanned as text only.
//!
//! ```text
//! off len field          contents
//!   0   4 magic          "uADM" (0x75 0x41 0x44 0x4D)
//!   4   2 version        u16, currently 2; receivers reject any other
//!   6   2 rank           u16 sender rank
//!   8   8 step           u64 training step the payload belongs to
//!  16   1 tag            payload kind: 0 dense / 1 topk / 2 eftopk
//!  17   1 flags          bit 0 = handshake (empty payload); rest 0
//!  18   4 loss           f32 bits, sender's local batch loss
//!  22   4 payload_len    u32 byte length of the payload section
//!  26   4 stats_count    u32 count of Quant4 bucket-stats records
//!  30   . payload        reducer payload
//!   .   4 crc32          IEEE CRC-32 over every preceding byte
//! ```

pub const MAGIC: [u8; 4] = *b"uADM";
pub const VERSION: u16 = 2;
pub const HEADER_BYTES: usize = 30;
pub const CRC_BYTES: usize = 4;
pub const FRAME_OVERHEAD: usize = HEADER_BYTES + CRC_BYTES;
