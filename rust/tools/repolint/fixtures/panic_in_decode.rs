//@ path: rust/src/dist/wire.rs
//@ expect: no-panic
// Seeded violations: panicking calls in a dist:: decode path, one bare
// and one with an allowlist tag that is missing its mandatory reason.
// Never compiled — scanned as text only.

pub fn decode_fixture(buf: &[u8]) -> u32 {
    let first = buf.first().unwrap();
    if *first > 7 {
        panic!("bad frame");
    }
    // repolint: allow(no-panic)
    let second = buf.get(1).expect("two bytes");
    u32::from(*first) + u32::from(*second)
}
