//! repolint CLI: `repolint [--root DIR] [--self-test]`.
//!
//! Exit status 0 means the tree satisfies every rule (or, with
//! `--self-test`, that every rule fires on its seeded fixture);
//! violations are printed one per line as `file:line: [rule] message`
//! and exit with status 1. `make lint` runs the self-test first, then
//! the repo pass, so a rule that silently stopped matching can never
//! green-light the tree.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut self_test = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--self-test" => self_test = true,
            "--root" => match args.next() {
                Some(d) => root = Some(PathBuf::from(d)),
                None => {
                    eprintln!("repolint: --root needs a directory argument");
                    return ExitCode::FAILURE;
                }
            },
            "--help" | "-h" => {
                println!("usage: repolint [--root DIR] [--self-test]");
                println!("rules: {}", repolint::RULES.join(", "));
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("repolint: unknown argument `{other}` (see --help)");
                return ExitCode::FAILURE;
            }
        }
    }

    if self_test {
        return match repolint::self_test() {
            Ok(n) => {
                println!("repolint self-test: {n} fixture checks passed, every rule fires");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("repolint self-test FAILED: {e}");
                ExitCode::FAILURE
            }
        };
    }

    let root = root.unwrap_or_else(|| PathBuf::from("."));
    if !root.join("rust/src/dist/wire.rs").is_file() {
        eprintln!(
            "repolint: {} does not look like the repo root (rust/src/dist/wire.rs not found); \
             run from the repo root or pass --root",
            root.display()
        );
        return ExitCode::FAILURE;
    }
    match repolint::lint_repo(&root) {
        Ok(v) if v.is_empty() => {
            println!("repolint: clean ({} rules)", repolint::RULES.len());
            ExitCode::SUCCESS
        }
        Ok(v) => {
            for violation in &v {
                eprintln!("{violation}");
            }
            eprintln!("repolint: {} violation(s)", v.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("repolint: io error: {e}");
            ExitCode::FAILURE
        }
    }
}
