//! repolint — machine-checked repo invariants for the MicroAdam tree.
//!
//! The crate is a static-analysis pass over the repository's own Rust
//! sources (plus the normative wire spec in `rust/src/dist/README.md`).
//! It exists so the invariants the docs promise cannot silently drift
//! from the code that implements them. Seven rules:
//!
//! * **`unsafe-safety`** — every `unsafe` occurrence must carry a
//!   `// SAFETY:` comment on the same line or within the five lines
//!   above it, stating the invariant the block relies on.
//! * **`no-panic`** — the `dist::` wire/transport/reducer decode and
//!   teardown paths ([`NO_PANIC_FILES`]) must not call
//!   `.unwrap()` / `.expect(` / `panic!` / `unreachable!` / `todo!` /
//!   `unimplemented!` outside `#[cfg(test)]` / `#[cfg(loom)]` modules.
//!   Typed errors (`WireError`, `anyhow::Result`) are required; a
//!   structurally-infallible case may be kept with an inline allowlist
//!   comment `// repolint: allow(no-panic): <reason>` on the same or the
//!   preceding line (the reason is mandatory).
//! * **`wire-spec`** — the normative constants in
//!   `rust/src/dist/wire.rs` (magic, version, 30-byte header, 4-byte
//!   CRC, 34-byte frame overhead, header field order) must match the
//!   numbers written in `rust/src/dist/README.md` §2, row for row.
//! * **`topology-spec`** — the hop-frame numbers in
//!   `rust/src/dist/README.md` §10 (the hop flag's bit position and
//!   value, the fan-in prefix layout and its byte count) must match the
//!   `FLAG_HOP` / `HOP_PREFIX_BYTES` constants in `wire.rs`.
//! * **`lossy-cast`** — the bytes-accounting functions
//!   ([`ACCOUNTING_FNS`]: `wire_bytes_per_rank`, `state_bytes`, …) must
//!   not contain lossy `as` casts (`as u32`, `as i64`, `as f64`, …);
//!   only `as u64` and `as usize` are widening on every supported
//!   target and therefore allowed. Allowlist syntax:
//!   `// repolint: allow(lossy-cast): <reason>`.
//! * **`hot-path-clock`** — the step-engine hot paths
//!   ([`HOT_PATH_CLOCK_DIRS`]: `exec::`, `optim::`) must not read the
//!   wall clock directly (`Instant::now()` / `SystemTime::now()`):
//!   timing there belongs to the `trace::` layer, whose entry points are
//!   gated on the tracing flag and free when tracing is off. An
//!   intentional clock read stays with
//!   `// repolint: allow(hot-path-clock): <reason>`.
//! * **`simd-twin`** — every file that gates code on the `simd` cargo
//!   feature (and every file under `rust/src/simd/`) must name its
//!   always-compiled scalar twin in a doc comment (`Scalar twin: …`),
//!   so each vector kernel's bit-exactness oracle stays discoverable
//!   from the kernel itself. Allowlist:
//!   `// repolint: allow(simd-twin): <reason>`.
//!
//! The scanner is line-oriented but lexes comments, strings (including
//! raw strings), and char literals so that rule patterns never match
//! inside string literals or prose. It is deliberately not a full Rust
//! parser: the rules are all local, and a pattern-level scanner keeps
//! the tool dependency-free (the workspace's no-new-deps rule applies
//! to its lint tool too).

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Names of every rule, in the order they are documented above.
pub const RULES: &[&str] = &[
    "unsafe-safety",
    "no-panic",
    "wire-spec",
    "topology-spec",
    "lossy-cast",
    "hot-path-clock",
    "simd-twin",
];

/// Files (matched by path suffix) subject to the `no-panic` rule: the
/// `dist::` wire/transport/reducer decode paths the spec requires to
/// fail with typed errors rather than abort the process.
pub const NO_PANIC_FILES: &[&str] = &[
    "rust/src/dist/wire.rs",
    "rust/src/dist/transport.rs",
    "rust/src/dist/reducer.rs",
    "rust/src/dist/trainer.rs",
    "rust/src/dist/replica.rs",
];

/// Function names whose bodies form the bytes-accounting paths checked
/// by the `lossy-cast` rule.
pub const ACCOUNTING_FNS: &[&str] = &[
    "wire_bytes_per_rank",
    "state_bytes",
    "paper_state_bytes",
    "residual_state_bytes",
    "frame_bytes_per_rank",
    "wire_bytes_total",
    "encoded_len",
    "slab_bytes_per_rank",
];

/// One rule violation, formatted `file:line: [rule] message`.
#[derive(Debug, Clone)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One source line after lexical preparation: `code` keeps the code with
/// string/char contents blanked (quotes preserved) and comments removed;
/// `comment` holds the concatenated comment text of the line.
pub struct PreparedLine {
    pub code: String,
    pub comment: String,
}

/// A lexed source file plus a mask of lines inside `#[cfg(test)]` /
/// `#[cfg(loom)]` modules (exempt from the `no-panic` rule).
pub struct Prepared {
    pub lines: Vec<PreparedLine>,
    pub masked: Vec<bool>,
}

/// Lex `src` into per-line code/comment channels. Handles line and
/// (nested) block comments, string literals with escapes, raw strings
/// (`r"…"`, `r#"…"#`, byte variants), char literals, and lifetimes.
pub fn prepare(src: &str) -> Prepared {
    enum St {
        Code,
        Line,
        Block(u32),
        Str,
        RawStr(u8),
    }
    let chars: Vec<char> = src.chars().collect();
    let mut lines: Vec<PreparedLine> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut st = St::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, St::Line) {
                st = St::Code;
            }
            lines.push(PreparedLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    st = St::Line;
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::Block(1);
                    i += 2;
                    continue;
                }
                // Raw (byte) strings: r"…", r#"…"#, br"…", br#"…"#.
                if c == 'r' || (c == 'b' && chars.get(i + 1) == Some(&'r')) {
                    let j = if c == 'b' { i + 2 } else { i + 1 };
                    let mut hashes = 0usize;
                    let mut k = j;
                    while chars.get(k) == Some(&'#') {
                        hashes += 1;
                        k += 1;
                    }
                    if chars.get(k) == Some(&'"') {
                        code.push('"');
                        st = St::RawStr(hashes as u8);
                        i = k + 1;
                        continue;
                    }
                }
                if c == '"' {
                    code.push('"');
                    st = St::Str;
                    i += 1;
                    continue;
                }
                if c == '\'' {
                    match (chars.get(i + 1), chars.get(i + 2)) {
                        (Some('\\'), _) => {
                            // Escaped char literal: skip the escape, then
                            // scan to the closing quote.
                            code.push('\'');
                            code.push('\'');
                            let mut k = i + 3;
                            while k < chars.len() && chars[k] != '\'' {
                                k += 1;
                            }
                            i = k + 1;
                            continue;
                        }
                        (Some(_), Some('\'')) => {
                            // Plain char literal 'x'.
                            code.push('\'');
                            code.push(' ');
                            code.push('\'');
                            i += 3;
                            continue;
                        }
                        _ => {
                            // Lifetime tick.
                            code.push('\'');
                            i += 1;
                            continue;
                        }
                    }
                }
                code.push(c);
                i += 1;
            }
            St::Line => {
                comment.push(c);
                i += 1;
            }
            St::Block(d) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::Block(d + 1);
                    i += 2;
                    continue;
                }
                comment.push(c);
                i += 1;
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                    continue;
                }
                if c == '"' {
                    code.push('"');
                    st = St::Code;
                }
                i += 1;
            }
            St::RawStr(h) => {
                if c == '"' {
                    let closed = (0..h as usize).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if closed {
                        code.push('"');
                        st = St::Code;
                        i += 1 + h as usize;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(PreparedLine { code, comment });
    }
    let masked = mask_test_mods(&lines);
    Prepared { lines, masked }
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Whole-word search for `word` in `hay`; returns true on a match whose
/// neighbours are not identifier characters.
fn contains_word(hay: &str, word: &str) -> bool {
    let bytes = hay.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = hay[start..].find(word) {
        let p = start + pos;
        let before_ok = p == 0 || !is_word_byte(bytes[p - 1]);
        let after = p + word.len();
        let after_ok = after >= bytes.len() || !is_word_byte(bytes[after]);
        if before_ok && after_ok {
            return true;
        }
        start = p + word.len();
    }
    false
}

/// Mark the line extents of `#[cfg(test)]` and `#[cfg(loom)]` modules.
fn mask_test_mods(lines: &[PreparedLine]) -> Vec<bool> {
    let mut masked = vec![false; lines.len()];
    let mut i = 0usize;
    while i < lines.len() {
        let gate = lines[i].code.contains("#[cfg(test)]")
            || lines[i].code.contains("#[cfg(loom)]")
            || lines[i].code.contains("#[cfg(all(test");
        if !gate {
            i += 1;
            continue;
        }
        // The gated item must be a module within the next few lines
        // (further attributes may sit in between).
        let mut m = None;
        for j in i..lines.len().min(i + 4) {
            if contains_word(&lines[j].code, "mod") {
                m = Some(j);
                break;
            }
        }
        let Some(m) = m else {
            i += 1;
            continue;
        };
        // Mask from the attribute through the module's closing brace
        // (or through `mod name;` for out-of-line modules).
        let mut depth = 0i64;
        let mut seen_brace = false;
        let mut end = lines.len() - 1;
        for j in m..lines.len() {
            for ch in lines[j].code.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        seen_brace = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if seen_brace && depth <= 0 {
                end = j;
                break;
            }
            if !seen_brace && lines[j].code.contains(';') {
                end = j;
                break;
            }
        }
        for flag in masked.iter_mut().take(end + 1).skip(i) {
            *flag = true;
        }
        i = end + 1;
    }
    masked
}

/// Inline-allowlist check: `// repolint: allow(<key>): <reason>` on the
/// same or the immediately preceding line, with a non-empty reason.
fn allowlisted(p: &Prepared, line: usize, key: &str) -> bool {
    let tag = format!("repolint: allow({key})");
    let lo = line.saturating_sub(1);
    for l in &p.lines[lo..=line] {
        if let Some(pos) = l.comment.find(&tag) {
            let reason = l.comment[pos + tag.len()..]
                .trim_start_matches(|c: char| c == ':' || c == '-' || c.is_whitespace());
            if !reason.trim().is_empty() {
                return true;
            }
        }
    }
    false
}

/// Rule `unsafe-safety`: every `unsafe` token needs a `SAFETY:` comment
/// on the same line or within the five lines above.
pub fn rule_unsafe_safety(path: &str, p: &Prepared) -> Vec<Violation> {
    let mut out = Vec::new();
    for (i, line) in p.lines.iter().enumerate() {
        if !contains_word(&line.code, "unsafe") {
            continue;
        }
        let lo = i.saturating_sub(5);
        let documented = p.lines[lo..=i].iter().any(|l| l.comment.contains("SAFETY:"));
        if !documented {
            out.push(Violation {
                file: path.to_string(),
                line: i + 1,
                rule: "unsafe-safety",
                msg: "`unsafe` without a `// SAFETY:` comment within the 5 lines above — \
                      state the invariant the block relies on"
                    .to_string(),
            });
        }
    }
    out
}

const PANIC_PATTERNS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

/// Rule `no-panic`: forbid panicking calls in the `dist::` decode and
/// teardown paths (outside test/loom modules), unless allowlisted.
pub fn rule_no_panic(path: &str, p: &Prepared) -> Vec<Violation> {
    if !NO_PANIC_FILES.iter().any(|f| path.ends_with(f)) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, line) in p.lines.iter().enumerate() {
        if p.masked[i] {
            continue;
        }
        for pat in PANIC_PATTERNS {
            if line.code.contains(pat) && !allowlisted(p, i, "no-panic") {
                out.push(Violation {
                    file: path.to_string(),
                    line: i + 1,
                    rule: "no-panic",
                    msg: format!(
                        "`{pat}` in a dist:: wire/transport path — return a typed \
                         WireError/anyhow error, or justify with \
                         `// repolint: allow(no-panic): <reason>`"
                    ),
                });
            }
        }
    }
    out
}

/// Directories (matched by path substring) subject to the
/// `hot-path-clock` rule: the fused step engine and its worker pool,
/// whose inner loops run per block per step and must stay free of
/// unconditional clock reads.
pub const HOT_PATH_CLOCK_DIRS: &[&str] = &["rust/src/exec/", "rust/src/optim/"];

const CLOCK_PATTERNS: &[&str] = &["Instant::now()", "SystemTime::now()"];

/// Rule `hot-path-clock`: forbid direct wall-clock reads in the
/// `exec::`/`optim::` hot paths (outside test/loom modules) — timing
/// belongs to `trace::`, whose gated entry points cost one relaxed load
/// when tracing is off. Allowlist: `// repolint: allow(hot-path-clock):
/// <reason>`.
pub fn rule_hot_path_clock(path: &str, p: &Prepared) -> Vec<Violation> {
    if !HOT_PATH_CLOCK_DIRS.iter().any(|d| path.contains(d)) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, line) in p.lines.iter().enumerate() {
        if p.masked[i] {
            continue;
        }
        for pat in CLOCK_PATTERNS {
            if line.code.contains(pat) && !allowlisted(p, i, "hot-path-clock") {
                out.push(Violation {
                    file: path.to_string(),
                    line: i + 1,
                    rule: "hot-path-clock",
                    msg: format!(
                        "`{pat}` in an exec::/optim:: hot path — route timing through \
                         the gated `trace::` layer, or justify with \
                         `// repolint: allow(hot-path-clock): <reason>`"
                    ),
                });
            }
        }
    }
    out
}

/// Rule `simd-twin`: a file that gates code on the `simd` cargo feature
/// (or lives under `rust/src/simd/`) must reference its always-compiled
/// scalar twin in a doc comment (`Scalar twin: …`), so the parity oracle
/// for each vector kernel is discoverable from the kernel itself. The
/// feature name lives inside a string literal of the `cfg` attribute and
/// the lexer blanks string contents, so the gate is matched on the raw
/// source line, with the prepared code channel confirming it is code
/// rather than prose.
pub fn rule_simd_twin(path: &str, src: &str, p: &Prepared) -> Vec<Violation> {
    let in_simd_dir = path.contains("rust/src/simd/");
    let gate_line = src.lines().enumerate().find_map(|(i, l)| {
        let is_code =
            p.lines.get(i).map(|pl| pl.code.contains("feature =")).unwrap_or(false);
        (l.contains("feature = \"simd\"") && is_code).then_some(i)
    });
    let (line, what) = match (in_simd_dir, gate_line) {
        (true, g) => (g.unwrap_or(0), "file under rust/src/simd/"),
        (false, Some(i)) => (i, "`cfg(feature = \"simd\")`-gated code"),
        (false, None) => return Vec::new(),
    };
    let documented = p.lines.iter().any(|l| l.comment.contains("Scalar twin:"));
    if documented || allowlisted(p, line, "simd-twin") {
        return Vec::new();
    }
    vec![Violation {
        file: path.to_string(),
        line: line + 1,
        rule: "simd-twin",
        msg: format!(
            "{what} without a `Scalar twin:` doc reference — name the \
             always-compiled scalar kernel that is this code's bit-exactness \
             oracle, or justify with `// repolint: allow(simd-twin): <reason>`"
        ),
    }]
}

const LOSSY_TARGETS: &[&str] = &[
    "u8", "u16", "u32", "u128", "i8", "i16", "i32", "i64", "i128", "f32", "f64", "isize",
];

/// Rule `lossy-cast`: inside the accounting functions, forbid `as` casts
/// to any type that can truncate a byte count. `as u64` / `as usize`
/// stay legal (widening on every supported target).
pub fn rule_lossy_cast(path: &str, p: &Prepared) -> Vec<Violation> {
    let mut out = Vec::new();
    for (name, start, end) in fn_regions(p, ACCOUNTING_FNS) {
        for (j, line) in p.lines.iter().enumerate().take(end + 1).skip(start) {
            for ty in LOSSY_TARGETS {
                let pat = format!(" as {ty}");
                let bytes = line.code.as_bytes();
                let mut s = 0usize;
                while let Some(pos) = line.code[s..].find(&pat) {
                    let after = s + pos + pat.len();
                    s = after;
                    if after < bytes.len() && is_word_byte(bytes[after]) {
                        continue; // e.g. ` as u16x8` — a different identifier
                    }
                    if !allowlisted(p, j, "lossy-cast") {
                        out.push(Violation {
                            file: path.to_string(),
                            line: j + 1,
                            rule: "lossy-cast",
                            msg: format!(
                                "lossy `as {ty}` inside accounting fn `{name}` — byte \
                                 counts must stay usize/u64, or justify with \
                                 `// repolint: allow(lossy-cast): <reason>`"
                            ),
                        });
                    }
                }
            }
        }
    }
    out
}

/// Locate the line extents of function bodies whose names are in
/// `names`. Bodiless trait declarations (`fn f(…) -> T;`) are skipped.
fn fn_regions(p: &Prepared, names: &[&str]) -> Vec<(String, usize, usize)> {
    let mut out = Vec::new();
    for (i, line) in p.lines.iter().enumerate() {
        for &name in names {
            let pat = format!("fn {name}");
            let Some(pos) = line.code.find(&pat) else {
                continue;
            };
            let bytes = line.code.as_bytes();
            let after = pos + pat.len();
            if after < bytes.len() && is_word_byte(bytes[after]) {
                continue; // prefix of a longer identifier
            }
            let mut depth = 0i64;
            let mut seen_brace = false;
            let mut body = None;
            'scan: for j in i..p.lines.len() {
                for ch in p.lines[j].code.chars() {
                    match ch {
                        '{' => {
                            depth += 1;
                            seen_brace = true;
                        }
                        '}' => {
                            depth -= 1;
                            if seen_brace && depth == 0 {
                                body = Some(j);
                                break 'scan;
                            }
                        }
                        ';' if !seen_brace && depth == 0 => break 'scan,
                        _ => {}
                    }
                }
            }
            if let Some(end) = body {
                out.push((name.to_string(), i, end));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// wire-spec: pin rust/src/dist/wire.rs against rust/src/dist/README.md
// ---------------------------------------------------------------------

#[derive(Debug, PartialEq)]
struct Row {
    off: usize,
    len: usize,
    name: String,
}

/// Parse `off len name …` rows (the fixed-width header fields) from an
/// iterator of raw table lines.
fn parse_rows<'a>(lines: impl Iterator<Item = &'a str>) -> Vec<Row> {
    let mut out = Vec::new();
    for l in lines {
        let l = l.trim_start().trim_start_matches("//!").trim();
        let mut it = l.split_whitespace();
        let (Some(a), Some(b), Some(c)) = (it.next(), it.next(), it.next()) else {
            continue;
        };
        let (Ok(off), Ok(len)) = (a.parse::<usize>(), b.parse::<usize>()) else {
            continue;
        };
        if !c.bytes().all(is_word_byte) {
            continue;
        }
        out.push(Row {
            off,
            len,
            name: c.to_string(),
        });
    }
    out
}

/// Offset of a named variable-length row (`30   .  payload`): the len
/// column is non-numeric, so [`parse_rows`] skips it.
fn named_offset<'a>(lines: impl Iterator<Item = &'a str>, name: &str) -> Option<usize> {
    for l in lines {
        let l = l.trim_start().trim_start_matches("//!").trim();
        let mut it = l.split_whitespace();
        let (Some(a), Some(_), Some(c)) = (it.next(), it.next(), it.next()) else {
            continue;
        };
        if c == name {
            if let Ok(off) = a.parse::<usize>() {
                return Some(off);
            }
        }
    }
    None
}

/// Offset of the variable-length `payload` row (`30   .  payload`).
fn payload_offset<'a>(lines: impl Iterator<Item = &'a str>) -> Option<usize> {
    named_offset(lines, "payload")
}

fn parse_const(src: &str, name: &str) -> Option<(usize, u64)> {
    for (i, l) in src.lines().enumerate() {
        let t = l.trim();
        let Some(rest) = t.strip_prefix(&format!("pub const {name}:")) else {
            continue;
        };
        let Some(eq) = rest.find('=') else { continue };
        let v = rest[eq + 1..].trim().trim_end_matches(';').trim();
        if let Ok(n) = v.parse::<u64>() {
            return Some((i + 1, n));
        }
    }
    None
}

fn all_integers(line: &str) -> Vec<u64> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in line.chars() {
        if c.is_ascii_digit() {
            cur.push(c);
        } else if !cur.is_empty() {
            if let Ok(n) = cur.parse() {
                out.push(n);
            }
            cur.clear();
        }
    }
    if let Ok(n) = cur.parse() {
        out.push(n);
    }
    out
}

/// Rule `wire-spec` over in-memory sources (the repo runner reads the
/// real files; the self-test feeds drifted fixtures).
pub fn rule_wire_spec(wire_src: &str, readme_src: &str) -> Vec<Violation> {
    const WIRE: &str = "rust/src/dist/wire.rs";
    const README: &str = "rust/src/dist/README.md";
    let mut out = Vec::new();
    let mut fail = |file: &str, line: usize, msg: String| {
        out.push(Violation {
            file: file.to_string(),
            line,
            rule: "wire-spec",
            msg,
        });
    };

    // --- constants from wire.rs -------------------------------------
    let magic = wire_src
        .lines()
        .enumerate()
        .find(|(_, l)| l.trim_start().starts_with("pub const MAGIC:"))
        .and_then(|(i, l)| {
            let s = l.split("b\"").nth(1)?.split('"').next()?;
            Some((i + 1, s.to_string()))
        });
    let version = parse_const(wire_src, "VERSION");
    let header = parse_const(wire_src, "HEADER_BYTES");
    let crc = parse_const(wire_src, "CRC_BYTES");
    let Some((_, magic)) = magic else {
        fail(WIRE, 1, "couldn't locate `pub const MAGIC: [u8; 4] = *b\"…\"`".into());
        return out;
    };
    let (Some((_, version)), Some((_, header)), Some((_, crc))) = (version, header, crc) else {
        fail(
            WIRE,
            1,
            "couldn't locate VERSION / HEADER_BYTES / CRC_BYTES constants".into(),
        );
        return out;
    };
    let overhead = header + crc;
    match wire_src
        .lines()
        .enumerate()
        .find(|(_, l)| l.trim_start().starts_with("pub const FRAME_OVERHEAD:"))
    {
        Some((i, l)) if l.contains("HEADER_BYTES") && l.contains("CRC_BYTES") => {
            let _ = i;
        }
        Some((i, _)) => fail(
            WIRE,
            i + 1,
            "FRAME_OVERHEAD must be defined as HEADER_BYTES + CRC_BYTES".into(),
        ),
        None => fail(WIRE, 1, "couldn't locate `pub const FRAME_OVERHEAD`".into()),
    }

    // --- header table from the wire.rs module doc -------------------
    let doc_lines = || wire_src.lines().filter(|l| l.trim_start().starts_with("//!"));
    let wire_rows = parse_rows(doc_lines());
    let wire_payload = payload_offset(doc_lines());

    // --- README §2 region -------------------------------------------
    let lines: Vec<&str> = readme_src.lines().collect();
    let sec_start = lines.iter().position(|l| l.starts_with("## 2."));
    let Some(sec_start) = sec_start else {
        fail(README, 1, "couldn't locate section `## 2.` (frame layout)".into());
        return out;
    };
    let sec_end = lines[sec_start + 1..]
        .iter()
        .position(|l| l.starts_with("## "))
        .map(|p| sec_start + 1 + p)
        .unwrap_or(lines.len());
    let sec = &lines[sec_start..sec_end];
    let readme_rows = parse_rows(sec.iter().copied());
    let readme_payload = payload_offset(sec.iter().copied());

    // --- cross-checks ------------------------------------------------
    if wire_rows.is_empty() {
        fail(WIRE, 1, "module doc has no parseable `off len field` table".into());
    }
    if readme_rows.is_empty() {
        fail(README, sec_start + 1, "§2 has no parseable `offset len field` table".into());
    }
    if !wire_rows.is_empty() && !readme_rows.is_empty() && wire_rows != readme_rows {
        fail(
            README,
            sec_start + 1,
            format!(
                "§2 header table disagrees with the wire.rs module doc \
                 (README: {:?}; wire.rs: {:?})",
                readme_rows
                    .iter()
                    .map(|r| format!("{}@{}+{}", r.name, r.off, r.len))
                    .collect::<Vec<_>>(),
                wire_rows
                    .iter()
                    .map(|r| format!("{}@{}+{}", r.name, r.off, r.len))
                    .collect::<Vec<_>>(),
            ),
        );
    }
    // Field contiguity: offsets tile [0, HEADER_BYTES) exactly.
    let mut expect = 0usize;
    for r in &readme_rows {
        if r.off != expect {
            fail(
                README,
                sec_start + 1,
                format!("field `{}` at offset {} — expected {}", r.name, r.off, expect),
            );
        }
        expect = r.off + r.len;
    }
    if !readme_rows.is_empty() && expect as u64 != header {
        fail(
            README,
            sec_start + 1,
            format!("fixed header fields end at {expect}, HEADER_BYTES is {header}"),
        );
    }
    for (file, off) in [(WIRE, wire_payload), (README, readme_payload)] {
        match off {
            Some(o) if o as u64 == header => {}
            Some(o) => fail(
                file,
                1,
                format!("payload row at offset {o}, HEADER_BYTES is {header}"),
            ),
            None => fail(file, 1, "couldn't locate the payload table row".into()),
        }
    }

    // README magic line: ASCII "uADM" = 75 41 44 4D.
    match sec.iter().enumerate().find(|(_, l)| l.contains("ASCII \"")) {
        Some((i, l)) => {
            let quoted = l.split("ASCII \"").nth(1).and_then(|s| s.split('"').next());
            if quoted != Some(magic.as_str()) {
                fail(
                    README,
                    sec_start + i + 1,
                    format!("magic string {quoted:?} != wire.rs MAGIC {magic:?}"),
                );
            }
            let hex: Vec<u8> = l
                .rsplit('=')
                .next()
                .unwrap_or("")
                .split_whitespace()
                .filter_map(|t| u8::from_str_radix(t, 16).ok())
                .collect();
            if hex != magic.as_bytes() {
                fail(
                    README,
                    sec_start + i + 1,
                    format!("magic hex {hex:02x?} != MAGIC bytes {:02x?}", magic.as_bytes()),
                );
            }
        }
        None => fail(README, sec_start + 1, "couldn't locate the ASCII magic line".into()),
    }

    // README version: `this spec = N`.
    match sec.iter().enumerate().find(|(_, l)| l.contains("this spec =")) {
        Some((i, l)) => {
            let n = l
                .split("this spec =")
                .nth(1)
                .map(|s| all_integers(s))
                .and_then(|v| v.first().copied());
            if n != Some(version) {
                fail(
                    README,
                    sec_start + i + 1,
                    format!("spec version {n:?} != wire.rs VERSION {version}"),
                );
            }
        }
        None => fail(README, sec_start + 1, "couldn't locate `this spec = N`".into()),
    }

    // README overhead sentence: `= 30 header bytes + 4 CRC bytes = **34 bytes**`.
    match sec
        .iter()
        .enumerate()
        .find(|(_, l)| l.contains("frame overhead"))
    {
        Some((i, l)) => {
            let ints = all_integers(l);
            if ints != vec![header, crc, overhead] {
                fail(
                    README,
                    sec_start + i + 1,
                    format!(
                        "frame-overhead sentence says {ints:?}, constants say \
                         [{header}, {crc}, {overhead}]"
                    ),
                );
            }
        }
        None => fail(README, sec_start + 1, "couldn't locate the frame-overhead sentence".into()),
    }

    // README formula: `frame_bytes = wire_bytes_per_rank() + 34`.
    match sec
        .iter()
        .enumerate()
        .find(|(_, l)| l.contains("wire_bytes_per_rank() +"))
    {
        Some((i, l)) => {
            let n = l
                .split("wire_bytes_per_rank() +")
                .nth(1)
                .map(|s| all_integers(s))
                .and_then(|v| v.first().copied());
            if n != Some(overhead) {
                fail(
                    README,
                    sec_start + i + 1,
                    format!("frame_bytes formula adds {n:?}, FRAME_OVERHEAD is {overhead}"),
                );
            }
        }
        None => fail(
            README,
            sec_start + 1,
            "couldn't locate the `frame_bytes = wire_bytes_per_rank() + N` formula".into(),
        ),
    }
    out
}

// ---------------------------------------------------------------------
// topology-spec: pin the §10 hop-frame numbers against wire.rs
// ---------------------------------------------------------------------

/// Rule `topology-spec` over in-memory sources: the hop-flag value and
/// the hop-payload layout written in `rust/src/dist/README.md` §10 must
/// match the `FLAG_HOP` / `HOP_PREFIX_BYTES` constants in `wire.rs` —
/// the same two-sided drift check `wire-spec` runs for §2.
pub fn rule_topology_spec(wire_src: &str, readme_src: &str) -> Vec<Violation> {
    const WIRE: &str = "rust/src/dist/wire.rs";
    const README: &str = "rust/src/dist/README.md";
    let mut out = Vec::new();
    let mut fail = |file: &str, line: usize, msg: String| {
        out.push(Violation {
            file: file.to_string(),
            line,
            rule: "topology-spec",
            msg,
        });
    };

    let flag_hop = parse_const(wire_src, "FLAG_HOP");
    let prefix = parse_const(wire_src, "HOP_PREFIX_BYTES");
    let (Some((_, flag_hop)), Some((_, prefix))) = (flag_hop, prefix) else {
        fail(
            WIRE,
            1,
            "couldn't locate FLAG_HOP / HOP_PREFIX_BYTES constants".into(),
        );
        return out;
    };

    let lines: Vec<&str> = readme_src.lines().collect();
    let Some(sec_start) = lines.iter().position(|l| l.starts_with("## 10.")) else {
        fail(README, 1, "couldn't locate section `## 10.` (topologies)".into());
        return out;
    };
    let sec_end = lines[sec_start + 1..]
        .iter()
        .position(|l| l.starts_with("## "))
        .map(|p| sec_start + 1 + p)
        .unwrap_or(lines.len());
    let sec = &lines[sec_start..sec_end];

    // Hop-flag sentence: `The hop flag is \`flags\` bit B (value V, …)`.
    match sec.iter().enumerate().find(|(_, l)| l.contains("hop flag")) {
        Some((i, l)) => {
            let ints = all_integers(l);
            let expect = [u64::from(flag_hop.trailing_zeros()), flag_hop];
            if ints.len() < 2 || ints[..2] != expect {
                fail(
                    README,
                    sec_start + i + 1,
                    format!(
                        "hop-flag sentence carries {ints:?}, wire.rs FLAG_HOP = {flag_hop} \
                         (flags bit {})",
                        flag_hop.trailing_zeros()
                    ),
                );
            }
        }
        None => fail(README, sec_start + 1, "couldn't locate the hop-flag sentence".into()),
    }

    // Hop-payload table: the fixed prefix rows tile [0, HOP_PREFIX_BYTES)
    // and the variable `partial` row starts exactly there.
    let rows = parse_rows(sec.iter().copied());
    if rows.is_empty() {
        fail(README, sec_start + 1, "§10 has no parseable hop-payload table".into());
    }
    let mut expect = 0usize;
    for r in &rows {
        if r.off != expect {
            fail(
                README,
                sec_start + 1,
                format!("hop field `{}` at offset {} — expected {}", r.name, r.off, expect),
            );
        }
        expect = r.off + r.len;
    }
    if !rows.is_empty() && expect as u64 != prefix {
        fail(
            README,
            sec_start + 1,
            format!("hop prefix fields end at {expect}, HOP_PREFIX_BYTES is {prefix}"),
        );
    }
    match named_offset(sec.iter().copied(), "partial") {
        Some(o) if o as u64 == prefix => {}
        Some(o) => fail(
            README,
            sec_start + 1,
            format!("`partial` row at offset {o}, HOP_PREFIX_BYTES is {prefix}"),
        ),
        None => fail(README, sec_start + 1, "couldn't locate the `partial` table row".into()),
    }

    // The prefix byte count also appears in prose:
    // `\`wire::HOP_PREFIX_BYTES\` = **4 bytes**`.
    match sec
        .iter()
        .enumerate()
        .find(|(_, l)| l.contains("HOP_PREFIX_BYTES` ="))
    {
        Some((i, l)) => {
            let n = l
                .split("HOP_PREFIX_BYTES` =")
                .nth(1)
                .map(all_integers)
                .and_then(|v| v.first().copied());
            if n != Some(prefix) {
                fail(
                    README,
                    sec_start + i + 1,
                    format!("prefix sentence says {n:?}, HOP_PREFIX_BYTES is {prefix}"),
                );
            }
        }
        None => fail(
            README,
            sec_start + 1,
            "couldn't locate the `HOP_PREFIX_BYTES` prose sentence".into(),
        ),
    }
    out
}

// ---------------------------------------------------------------------
// Drivers
// ---------------------------------------------------------------------

/// Run the per-file rules on one source file.
pub fn lint_file(rel_path: &str, src: &str) -> Vec<Violation> {
    let p = prepare(src);
    let mut v = rule_unsafe_safety(rel_path, &p);
    v.extend(rule_no_panic(rel_path, &p));
    v.extend(rule_lossy_cast(rel_path, &p));
    v.extend(rule_hot_path_clock(rel_path, &p));
    v.extend(rule_simd_twin(rel_path, src, &p));
    v
}

/// Collect the `.rs` files under `<root>/rust` and `<root>/examples`,
/// skipping build output and the seeded-violation fixtures.
pub fn rust_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack: Vec<PathBuf> = ["rust", "examples"]
        .iter()
        .map(|d| root.join(d))
        .filter(|d| d.is_dir())
        .collect();
    while let Some(dir) = stack.pop() {
        for entry in fs::read_dir(&dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if path.is_dir() {
                if !matches!(name, "target" | "fixtures" | ".git") {
                    stack.push(path);
                }
            } else if name.ends_with(".rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Run every rule over the repository rooted at `root`.
pub fn lint_repo(root: &Path) -> io::Result<Vec<Violation>> {
    let mut out = Vec::new();
    for f in rust_files(root)? {
        let src = fs::read_to_string(&f)?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f.as_path())
            .display()
            .to_string();
        out.extend(lint_file(&rel, &src));
    }
    let wire = root.join("rust/src/dist/wire.rs");
    let readme = root.join("rust/src/dist/README.md");
    match (fs::read_to_string(&wire), fs::read_to_string(&readme)) {
        (Ok(w), Ok(r)) => {
            out.extend(rule_wire_spec(&w, &r));
            out.extend(rule_topology_spec(&w, &r));
        }
        _ => out.push(Violation {
            file: "rust/src/dist".to_string(),
            line: 0,
            rule: "wire-spec",
            msg: "wire.rs or README.md missing — wrong --root?".to_string(),
        }),
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Self-test: every rule must fire on its seeded fixture
// ---------------------------------------------------------------------

/// Per-file fixtures. Each declares its virtual repo path and the rule
/// it expects to trip (or `clean`) in `//@` header directives.
pub const FIXTURES: &[(&str, &str)] = &[
    (
        "unsafe_no_safety.rs",
        include_str!("../fixtures/unsafe_no_safety.rs"),
    ),
    (
        "panic_in_decode.rs",
        include_str!("../fixtures/panic_in_decode.rs"),
    ),
    ("lossy_cast.rs", include_str!("../fixtures/lossy_cast.rs")),
    (
        "hot_path_clock.rs",
        include_str!("../fixtures/hot_path_clock.rs"),
    ),
    (
        "simd_no_twin.rs",
        include_str!("../fixtures/simd_no_twin.rs"),
    ),
    ("clean.rs", include_str!("../fixtures/clean.rs")),
];

/// Drifted wire-spec pair (README claims a different version).
pub const WIRE_DRIFT: (&str, &str) = (
    include_str!("../fixtures/wire_drift/wire.rs"),
    include_str!("../fixtures/wire_drift/README.md"),
);

/// Drifted topology-spec pair (README §10 claims a different hop flag
/// and a wider fan-in prefix than wire.rs defines).
pub const TOPOLOGY_DRIFT: (&str, &str) = (
    include_str!("../fixtures/topology_drift/wire.rs"),
    include_str!("../fixtures/topology_drift/README.md"),
);

fn directive<'a>(src: &'a str, key: &str) -> Option<&'a str> {
    let tag = format!("//@ {key}:");
    src.lines()
        .find_map(|l| l.strip_prefix(&tag).map(str::trim))
}

/// Run the rules against the seeded fixtures; `Err` describes the first
/// rule that failed to behave. Returns the number of checks performed.
pub fn self_test() -> Result<usize, String> {
    let mut checks = 0usize;
    for (fname, src) in FIXTURES {
        let path = directive(src, "path")
            .ok_or_else(|| format!("{fname}: missing `//@ path:` directive"))?;
        let expect = directive(src, "expect")
            .ok_or_else(|| format!("{fname}: missing `//@ expect:` directive"))?;
        let got = lint_file(path, src);
        if expect == "clean" {
            if !got.is_empty() {
                return Err(format!(
                    "{fname}: expected clean, got {} violation(s): {}",
                    got.len(),
                    got[0]
                ));
            }
        } else {
            if !got.iter().any(|v| v.rule == expect) {
                return Err(format!("{fname}: rule `{expect}` did not fire"));
            }
            if let Some(stray) = got.iter().find(|v| v.rule != expect) {
                return Err(format!("{fname}: unexpected extra violation: {stray}"));
            }
        }
        checks += 1;
    }
    let drift = rule_wire_spec(WIRE_DRIFT.0, WIRE_DRIFT.1);
    if drift.is_empty() {
        return Err("wire_drift: rule `wire-spec` did not fire on the drifted pair".into());
    }
    checks += 1;
    let topo_drift = rule_topology_spec(TOPOLOGY_DRIFT.0, TOPOLOGY_DRIFT.1);
    if topo_drift.is_empty() {
        return Err("topology_drift: rule `topology-spec` did not fire on the drifted pair".into());
    }
    checks += 1;
    Ok(checks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_rule_fires_on_its_fixture() {
        match self_test() {
            Ok(n) => assert!(n >= 8, "expected at least 8 fixture checks, ran {n}"),
            Err(e) => panic!("self-test failed: {e}"),
        }
    }

    #[test]
    fn scanner_ignores_strings_and_comments() {
        let p = prepare(
            "fn f() {\n    let s = \"unsafe .unwrap() panic!\";\n    // unsafe in prose\n}\n",
        );
        assert!(!contains_word(&p.lines[1].code, "unsafe"));
        assert!(!p.lines[1].code.contains(".unwrap()"));
        assert!(contains_word(&p.lines[2].comment, "unsafe"));
    }

    #[test]
    fn char_literals_and_lifetimes_lex_cleanly() {
        let p = prepare("fn g<'a>(x: &'a str) -> char { let c = '\\''; let d = 'x'; 'q' }\n");
        // The lifetime must not swallow the rest of the line as a char
        // literal: `let d` survives in the code channel.
        assert!(p.lines[0].code.contains("let d"));
    }

    #[test]
    fn test_mod_lines_are_masked() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() { x.unwrap() }\n}\nfn c() {}\n";
        let p = prepare(src);
        assert!(!p.masked[0]);
        assert!(p.masked[1] && p.masked[2] && p.masked[3] && p.masked[4]);
        assert!(!p.masked[5]);
    }

    #[test]
    fn allowlist_requires_a_reason() {
        let with_reason =
            "//@ x\nfn f() {\n    // repolint: allow(no-panic): sized two lines above.\n    a.unwrap()\n}\n";
        let p = prepare(with_reason);
        assert!(allowlisted(&p, 3, "no-panic"));
        let bare = "fn f() {\n    // repolint: allow(no-panic)\n    a.unwrap()\n}\n";
        let p = prepare(bare);
        assert!(!allowlisted(&p, 2, "no-panic"));
    }

    #[test]
    fn accounting_fn_regions_skip_trait_declarations() {
        let src = "trait T {\n    fn state_bytes(&self) -> usize;\n}\nimpl T for S {\n    fn state_bytes(&self) -> usize {\n        self.n as u32 as usize\n    }\n}\n";
        let p = prepare(src);
        let regions = fn_regions(&p, &["state_bytes"]);
        assert_eq!(regions.len(), 1);
        let v = rule_lossy_cast("rust/src/x.rs", &p);
        assert_eq!(v.len(), 1, "exactly the impl-body cast: {v:?}");
    }
}
