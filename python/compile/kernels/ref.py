"""Pure-jnp reference oracles for the Pallas kernels.

Everything in this file is the *correctness ground truth*: slow, dense,
obviously-right implementations of

  * block-wise 4-bit quantization of the error-feedback (EF) accumulator
    (Algorithm 2, procedures Q / Q^-1), deterministic nearest rounding as in
    the practical algorithm plus the randomized-rounding variant analysed in
    Lemma 1;
  * the MicroAdam dynamic statistics + parameter update (Algorithm 2,
    ADAMSTATS, applied per block as in Algorithm 1 lines 11-13);
  * a dense AdamW step (baseline oracle used to sanity-check the adamw_step
    artifact graph).

The Pallas kernels in `quant_pallas.py` / `microadam_pallas.py` are tested
against these oracles by `python/tests/`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _levels(bits: int) -> int:
    """Number of quantization steps for b bits (2^b - 1)."""
    return (1 << bits) - 1


# ---------------------------------------------------------------------------
# Quantization (Algorithm 2: Q / Q^-1), bucket-wise.
# ---------------------------------------------------------------------------

def quant_bucket_ref(x: jnp.ndarray, bits: int = 4):
    """Quantize one bucket deterministically (round-to-nearest).

    Returns (codes uint8 in [0, 2^bits-1], delta, Delta). A constant bucket
    (Delta == delta) maps to all-zero codes.
    """
    lo = jnp.min(x)
    hi = jnp.max(x)
    u = (hi - lo) / _levels(bits)
    safe_u = jnp.where(u > 0, u, 1.0)
    q = jnp.floor((x - lo) / safe_u + 0.5)
    q = jnp.clip(q, 0, _levels(bits)).astype(jnp.uint8)
    q = jnp.where(u > 0, q, jnp.zeros_like(q))
    return q, lo, hi


def quant_bucket_stochastic_ref(x: jnp.ndarray, key: jax.Array, bits: int = 4):
    """Lemma-1 randomized rounding: floor((x - delta)/u + xi), xi ~ U[0,1].

    Unbiased: E[Q^-1(Q(x))] = x. Used by the property tests, not by the
    deterministic artifact path.
    """
    lo = jnp.min(x)
    hi = jnp.max(x)
    u = (hi - lo) / _levels(bits)
    safe_u = jnp.where(u > 0, u, 1.0)
    xi = jax.random.uniform(key, x.shape)
    q = jnp.floor((x - lo) / safe_u + xi)
    q = jnp.clip(q, 0, _levels(bits)).astype(jnp.uint8)
    q = jnp.where(u > 0, q, jnp.zeros_like(q))
    return q, lo, hi


def dequant_bucket_ref(q: jnp.ndarray, lo, hi, bits: int = 4) -> jnp.ndarray:
    u = (hi - lo) / _levels(bits)
    return q.astype(jnp.float32) * u + lo


def quant4_ref(x: jnp.ndarray, bucket: int):
    """Full-vector bucketed 4-bit quantization with nibble packing.

    x: (D,) with D % bucket == 0 and bucket even.
    Returns (packed uint8 (D//2,), delta (D//bucket,), Delta (D//bucket,)).
    Even elements occupy the low nibble, odd the high nibble — the layout the
    paper's CUDA kernel uses for its d/2-byte uint8 EF array.
    """
    nq = x.shape[0] // bucket
    xb = x.reshape(nq, bucket)
    q, lo, hi = jax.vmap(lambda row: quant_bucket_ref(row, 4))(xb)
    qf = q.reshape(-1)
    packed = (qf[0::2] | (qf[1::2] << 4)).astype(jnp.uint8)
    return packed, lo, hi


def dequant4_ref(packed: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, bucket: int) -> jnp.ndarray:
    """Inverse of `quant4_ref`: (D//2,) u8 + per-bucket stats -> (D,) f32."""
    low = (packed & 0xF).astype(jnp.uint8)
    high = (packed >> 4).astype(jnp.uint8)
    q = jnp.stack([low, high], axis=1).reshape(-1)  # interleave back
    nq = lo.shape[0]
    qb = q.reshape(nq, bucket)
    x = jax.vmap(lambda row, l, h: dequant_bucket_ref(row, l, h, 4))(qb, lo, hi)
    return x.reshape(-1)


# ---------------------------------------------------------------------------
# MicroAdam dynamic statistics (ADAMSTATS) + update, dense reference.
# ---------------------------------------------------------------------------

def window_weights_ref(t, m: int, beta1: float, beta2: float):
    """Per-row scalar weights for the sliding window at (1-based) step t.

    Row i (0-based) of the ring buffer was last written at step
    w_i = largest s <= t with (s-1) % m == i; its decay exponent ("age") is
    (w - i) mod m where w = (t-1) % m. Rows never written yet (i >= t while
    t <= m) get weight zero. The returned weights fold in the (1-beta)
    factor and the bias correction 1 - beta^min(t, m), so
        m_hat = sum_i w1[i] * scatter(V_i)        (same shape for v_hat).
    """
    t = jnp.asarray(t, jnp.int32)
    w = jnp.mod(t - 1, m)
    rows = jnp.arange(m)
    age = jnp.mod(w - rows, m).astype(jnp.float32)
    valid = (rows < t).astype(jnp.float32)
    eff = jnp.minimum(t, m).astype(jnp.float32)

    def weights(beta):
        bc = 1.0 - beta**eff
        return valid * (1.0 - beta) * beta**age / bc

    return weights(beta1), weights(beta2)


def adamstats_ref(idx, vals, weights, dim: int, square: bool) -> jnp.ndarray:
    """ADAMSTATS for one block: z[I_i] += w_i * V_i (or V_i^2).

    idx, vals: (m, k) block-relative window rows; weights: (m,).
    Returns a dense (dim,) statistic; bias correction is already folded into
    `weights` (see window_weights_ref).
    """
    z = jnp.zeros((dim,), jnp.float32)
    m = idx.shape[0]
    for i in range(m):
        v = vals[i] * vals[i] if square else vals[i]
        z = z.at[idx[i]].add(weights[i] * v)
    return z


def microadam_update_block_ref(params, idx, vals, w1, w2, lr, eps):
    """Algorithm 1 lines 11-13 for one block of the flat parameter vector."""
    dim = params.shape[0]
    m_hat = adamstats_ref(idx, vals, w1, dim, square=False)
    v_hat = adamstats_ref(idx, vals, w2, dim, square=True)
    return params - lr * m_hat / (eps + jnp.sqrt(v_hat))


# ---------------------------------------------------------------------------
# Dense AdamW oracle (baseline graph check).
# ---------------------------------------------------------------------------

def adamw_step_ref(params, grads, m, v, t, lr, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0):
    """One decoupled-weight-decay Adam step on flat f32 vectors (oracle)."""
    m = beta1 * m + (1.0 - beta1) * grads
    v = beta2 * v + (1.0 - beta2) * grads * grads
    tf = jnp.asarray(t, jnp.float32)
    m_hat = m / (1.0 - beta1**tf)
    v_hat = v / (1.0 - beta2**tf)
    params = (1.0 - lr * weight_decay) * params - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return params, m, v
