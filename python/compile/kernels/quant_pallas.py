"""Pallas kernels for 4-bit block-wise EF quantization (Algorithm 2, Q/Q^-1).

Hardware adaptation (paper §3.1 -> TPU): the CUDA implementation stores the
error feedback as packed 4-bit nibbles in a d/2-byte uint8 HBM array, with
per-bucket (delta, Delta) metadata; each thread block quantizes one bucket.
Here the Pallas grid iterates over *tiles* of many buckets: BlockSpec slices
the flat vector into (TILE,)-shaped VMEM windows and the kernel reduces each
(TILE/BUCKET, BUCKET) view row-wise. Pack/unpack is pure vector shift/mask
work (VPU, no MXU involvement).

Why tiles instead of one-grid-step-per-bucket: interpret-mode pallas (the
only mode the CPU PJRT plugin can execute — Mosaic custom-calls don't run on
CPU) lowers the grid to a sequential scan, so grid length is pure overhead
at runtime. A tile of T buckets keeps the bucket-64 quantization semantics
of the paper (§B) while amortizing the scan; on a real TPU the tile maps to
one VMEM-resident block per core. TILE is the L1 performance knob swept in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LEVELS4 = 15  # 2^4 - 1 quantization steps


def _quant4_kernel(bucket: int, x_ref, packed_ref, lo_ref, hi_ref):
    """Quantize one tile: per-bucket (delta, Delta), 4-bit codes, packed nibbles."""
    x = x_ref[...].reshape(-1, bucket)  # (nb, bucket)
    lo = jnp.min(x, axis=1)
    hi = jnp.max(x, axis=1)
    u = (hi - lo) / LEVELS4
    safe_u = jnp.where(u > 0, u, 1.0)
    q = jnp.floor((x - lo[:, None]) / safe_u[:, None] + 0.5)
    q = jnp.clip(q, 0, LEVELS4).astype(jnp.uint8)
    q = jnp.where((u > 0)[:, None], q, jnp.zeros_like(q))
    qf = q.reshape(-1)
    # Even elements -> low nibble, odd -> high nibble (paper layout).
    packed_ref[...] = (qf[0::2] | (qf[1::2] << 4)).astype(jnp.uint8)
    lo_ref[...] = lo
    hi_ref[...] = hi


def _dequant4_kernel(bucket: int, packed_ref, lo_ref, hi_ref, x_ref):
    """Unpack one tile's nibbles and map codes back to values."""
    p = packed_ref[...]
    low = (p & 0xF).astype(jnp.float32)
    high = (p >> 4).astype(jnp.float32)
    q = jnp.stack([low, high], axis=1).reshape(-1, bucket)  # (nb, bucket)
    u = (hi_ref[...] - lo_ref[...]) / LEVELS4
    x_ref[...] = (q * u[:, None] + lo_ref[...][:, None]).reshape(-1)


def quant4(x: jnp.ndarray, bucket: int, tile: int | None = None):
    """Bucketed 4-bit quantize of a flat (D,) f32 vector via a Pallas kernel.

    Returns (packed u8 (D//2,), delta f32 (D//bucket,), Delta f32 (D//bucket,)).
    Requires D % tile == 0, tile % bucket == 0, bucket even.
    """
    d = x.shape[0]
    tile = tile or min(d, 65536)
    assert d % tile == 0 and tile % bucket == 0 and bucket % 2 == 0, (d, tile, bucket)
    grid = d // tile
    bpt = tile // bucket  # buckets per tile
    kernel = functools.partial(_quant4_kernel, bucket)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((tile,), lambda b: (b,))],
        out_specs=[
            pl.BlockSpec((tile // 2,), lambda b: (b,)),
            pl.BlockSpec((bpt,), lambda b: (b,)),
            pl.BlockSpec((bpt,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d // 2,), jnp.uint8),
            jax.ShapeDtypeStruct((d // bucket,), jnp.float32),
            jax.ShapeDtypeStruct((d // bucket,), jnp.float32),
        ],
        interpret=True,
    )(x)


def dequant4(packed: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
             bucket: int, tile: int | None = None) -> jnp.ndarray:
    """Inverse of `quant4`: (D//2,) u8 + per-bucket stats -> (D,) f32."""
    d = lo.shape[0] * bucket
    tile = tile or min(d, 65536)
    assert packed.shape[0] == d // 2 and d % tile == 0 and tile % bucket == 0
    grid = d // tile
    bpt = tile // bucket
    kernel = functools.partial(_dequant4_kernel, bucket)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile // 2,), lambda b: (b,)),
            pl.BlockSpec((bpt,), lambda b: (b,)),
            pl.BlockSpec((bpt,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(packed, lo, hi)
