"""Pallas kernel for the MicroAdam block update (Algorithm 1 lines 11-13).

This is the paper's compute hot-spot: re-deriving the Adam statistics
m_hat / v_hat from the sliding window G = (I, V) of block-wise sparse
gradients and applying the parameter update, all without ever materializing
dense optimizer state in HBM.

Hardware adaptation (paper §3.1, CUDA -> TPU):

  * CUDA launches one thread block per parameter block of size B_d < 2^15 and
    builds m_hat (first half) / v_hat (second half) in *shared memory*,
    indexing it directly with the block-relative int16 Top-K indices.
  * Here the Pallas grid runs over parameter blocks; BlockSpec slices the
    flat parameter vector into (B_d,) VMEM tiles and the window tensors into
    (m, 1, k_b) tiles. The dense z1/z2 scratch lives in VMEM (registers /
    vector memory under interpret=True), built by m successive scatter-adds
    with the block-relative indices — the exact analogue of the shared-memory
    accumulation. Indices within one window row are distinct (Top-K output),
    so each scatter-add is collision-free; rows accumulate sequentially.
  * Per-row decay weights beta^age, validity masking and bias correction are
    *folded into the (m,) weight vectors* w1/w2 at L2 (see
    model.window_weights), keeping the kernel a pure VMEM-local stencil with
    no transcendental ops.

VMEM budget per tile at defaults (B_d=4096, m=10, k_b=41):
  params 16 KiB + window (I+V) 2*10*41*4 B ~ 3.3 KiB + z1/z2 32 KiB
  ~ 52 KiB  << 16 MiB VMEM, so real-TPU occupancy is bounded by grid
  parallelism, not memory (see DESIGN.md §7 / EXPERIMENTS.md §Perf).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness on CPU is the contract, TPU numbers are estimates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_tile_kernel(m: int, block: int, w1_ref, w2_ref, scal_ref, p_ref, i_ref, v_ref, out_ref):
    """One tile of TC parameter blocks: scatter-accumulate z1/z2 + update.

    w1_ref/w2_ref: (m,) folded weights (decay * validity * (1-beta) / bias).
    scal_ref: (2,) = [lr, eps].
    p_ref: (TC*B_d,) params tile; i_ref/v_ref: (m, TC, k_b) window tiles with
    block-relative indices — the kernel adds per-block offsets so the dense
    scratch covers the whole tile. Within one window row all indices are
    distinct (Top-K output + disjoint block offsets), so each scatter-add is
    collision-free; rows accumulate sequentially, mirroring the paper's
    shared-memory loop.
    """
    dim = p_ref.shape[0]
    tc = dim // block
    offs = (jnp.arange(tc, dtype=jnp.int32) * block)[:, None]  # (TC, 1)
    z1 = jnp.zeros((dim,), jnp.float32)
    z2 = jnp.zeros((dim,), jnp.float32)
    # Static unroll over the window (m is small, 10-20 per the paper).
    for i in range(m):
        idx = (i_ref[i, :, :] + offs).reshape(-1)
        val = v_ref[i, :, :].reshape(-1)
        z1 = z1.at[idx].add(w1_ref[i] * val)
        z2 = z2.at[idx].add(w2_ref[i] * val * val)
    lr = scal_ref[0]
    eps = scal_ref[1]
    # Algorithm 1 line 13: theta <- theta - lr * m_hat / (eps + sqrt(v_hat)).
    out_ref[...] = p_ref[...] - lr * z1 / (eps + jnp.sqrt(z2))


def microadam_update(params: jnp.ndarray, w_idx: jnp.ndarray, w_val: jnp.ndarray,
                     w1: jnp.ndarray, w2: jnp.ndarray, lr, eps, block: int,
                     tile_blocks: int | None = None) -> jnp.ndarray:
    """Apply the MicroAdam update to the full flat parameter vector.

    params: (D,) f32, D % (tile_blocks*block) == 0.
    w_idx: (m, NB, k_b) int32 block-relative Top-K indices.
    w_val: (m, NB, k_b) f32 Top-K values (signed).
    w1/w2: (m,) folded per-row weights; lr/eps: scalars.
    tile_blocks: parameter blocks per grid step (interpret-mode scan
    amortization / TPU VMEM tile size — the L1 perf knob).
    """
    d = params.shape[0]
    assert d % block == 0, (d, block)
    nb = d // block
    m, nb2, kb = w_idx.shape
    assert nb2 == nb, (nb2, nb)
    tc = tile_blocks or min(nb, 16)
    assert nb % tc == 0, (nb, tc)
    grid = nb // tc
    tile = tc * block
    scal = jnp.stack([jnp.asarray(lr, jnp.float32), jnp.asarray(eps, jnp.float32)])
    kernel = functools.partial(_update_tile_kernel, m, block)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((m,), lambda b: (0,)),        # w1 (broadcast)
            pl.BlockSpec((m,), lambda b: (0,)),        # w2 (broadcast)
            pl.BlockSpec((2,), lambda b: (0,)),        # [lr, eps]
            pl.BlockSpec((tile,), lambda b: (b,)),     # params tile
            pl.BlockSpec((m, tc, kb), lambda b: (0, b, 0)),  # window indices
            pl.BlockSpec((m, tc, kb), lambda b: (0, b, 0)),  # window values
        ],
        out_specs=pl.BlockSpec((tile,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(w1, w2, scal, params, w_idx, w_val)
