"""AOT compile path: lower every L2 graph to HLO *text* + manifest.json.

Run once via `make artifacts`; the rust runtime then loads
`artifacts/*.hlo.txt` through `HloModuleProto::from_text_file` and never
touches python again.

HLO text (NOT `lowered.compile().serialize()` / proto bytes) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the published `xla` crate)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

The manifest records, for every artifact, the exact input/output signature
plus the static metadata the rust coordinator needs to drive it: model
parameter layout (name/shape/offset/init) and optimizer hyper-parameters
(m, B_d, k_b, B_q, tile). Rust validates its literals against this at load
time, so a stale artifact directory fails fast instead of mis-executing.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return _sanitize_hlo(comp.as_hlo_text())


def _sanitize_hlo(text: str) -> str:
    """Strip HLO-text attributes newer than xla_extension 0.5.1's parser.

    jax >= 0.8 prints `topk(..., k=N, largest=true)`; 0.5.1 only accepts the
    `k` attribute (largest selection is its only mode, so dropping the
    attribute is semantics-preserving). Anything else the old parser trips
    on gets added here with the same justification.
    """
    return text.replace(", largest=true", "")


def _sig(args) -> list[dict]:
    out = []
    for name, a in args:
        out.append({"name": name, "dtype": str(a.dtype), "shape": list(a.shape)})
    return out


def _param_meta(spec, d_pad: int) -> dict:
    params, off = [], 0
    for e in spec:
        params.append({
            "name": e.name, "shape": list(e.shape), "offset": off,
            "init": e.init, "init_std": e.init_std,
        })
        off += e.size
    return {"d_model_params": off, "d_padded": d_pad, "params": params}


class Emitter:
    def __init__(self, out_dir: str, force: bool):
        self.out_dir = out_dir
        self.force = force
        self.manifest: dict = {"artifacts": {}}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, inputs: list[tuple], outputs: list[str], meta: dict):
        """Lower fn at the given input signature and write <name>.hlo.txt."""
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        entry = {
            "file": f"{name}.hlo.txt",
            "inputs": _sig(inputs),
            "outputs": outputs,
            **meta,
        }
        self.manifest["artifacts"][name] = entry
        if os.path.exists(path) and not self.force:
            print(f"[aot] {name}: exists, skipping lower")
            return
        t0 = time.time()
        shapes = [a for _, a in inputs]
        lowered = jax.jit(fn).lower(*shapes)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] {name}: {len(text)/1e6:.2f} MB HLO text in {time.time()-t0:.1f}s")

    def finish(self):
        man = os.path.join(self.out_dir, "manifest.json")
        with open(man, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"[aot] wrote {man} ({len(self.manifest['artifacts'])} artifacts)")


F32, I32, U8 = jnp.float32, jnp.int32, jnp.uint8


def S(shape, dt=F32):
    return jax.ShapeDtypeStruct(shape, dt)


def emit_lm(em: Emitter, preset: str, opt: M.OptConfig):
    cfg = M.TRANSFORMER_PRESETS[preset]
    spec = M.transformer_param_spec(cfg, "lm")
    d = M.pad_to_tile(M.spec_size(spec), opt)
    fn = M.build_fwdbwd(lambda flat, tok, tgt: M.lm_loss(cfg, spec, flat, tok, tgt))
    em.emit(
        f"lm_{preset}", fn,
        inputs=[("flat_params", S((d,))),
                ("tokens", S((cfg.batch, cfg.seq), I32)),
                ("targets", S((cfg.batch, cfg.seq), I32))],
        outputs=["loss", "flat_grads"],
        meta={"kind": "fwdbwd", "model": "transformer_lm",
              "config": dataclasses.asdict(cfg), **{"layout": _param_meta(spec, d)}},
    )
    return d


def emit_cls(em: Emitter, preset: str, opt: M.OptConfig):
    cfg = M.TRANSFORMER_PRESETS[preset]
    spec = M.transformer_param_spec(cfg, "cls")
    d = M.pad_to_tile(M.spec_size(spec), opt)
    fn = M.build_fwdbwd(lambda flat, tok, lab: M.cls_loss(cfg, spec, flat, tok, lab))
    em.emit(
        f"cls_{preset}", fn,
        inputs=[("flat_params", S((d,))),
                ("tokens", S((cfg.batch, cfg.seq), I32)),
                ("labels", S((cfg.batch,), I32))],
        outputs=["loss", "flat_grads"],
        meta={"kind": "fwdbwd", "model": "transformer_cls",
              "config": dataclasses.asdict(cfg), **{"layout": _param_meta(spec, d)}},
    )
    # Inference graph for eval accuracy.
    em.emit(
        f"cls_{preset}_logits",
        lambda flat, tok: (M.cls_logits(cfg, spec, flat, tok),),
        inputs=[("flat_params", S((d,))), ("tokens", S((cfg.batch, cfg.seq), I32))],
        outputs=["logits"],
        meta={"kind": "infer", "model": "transformer_cls",
              "config": dataclasses.asdict(cfg), **{"layout": _param_meta(spec, d)}},
    )
    return d


def emit_cnn(em: Emitter, preset: str, opt: M.OptConfig):
    cfg = M.CNN_PRESETS[preset]
    spec = M.cnn_param_spec(cfg)
    d = M.pad_to_tile(M.spec_size(spec), opt)
    fn = M.build_fwdbwd(lambda flat, img, lab: M.cnn_loss(cfg, spec, flat, img, lab))
    em.emit(
        f"{preset}", fn,
        inputs=[("flat_params", S((d,))),
                ("images", S((cfg.batch, cfg.image, cfg.image, cfg.in_channels))),
                ("labels", S((cfg.batch,), I32))],
        outputs=["loss", "flat_grads"],
        meta={"kind": "fwdbwd", "model": "cnn",
              "config": dataclasses.asdict(cfg), **{"layout": _param_meta(spec, d)}},
    )
    em.emit(
        f"{preset}_logits",
        lambda flat, img: (M.cnn_logits(cfg, spec, flat, img),),
        inputs=[("flat_params", S((d,))),
                ("images", S((cfg.batch, cfg.image, cfg.image, cfg.in_channels)))],
        outputs=["logits"],
        meta={"kind": "infer", "model": "cnn",
              "config": dataclasses.asdict(cfg), **{"layout": _param_meta(spec, d)}},
    )
    return d


def _pick_tile_blocks(nb: int, cap: int = 256) -> int:
    """Largest divisor of nb at most `cap`.

    Perf (EXPERIMENTS.md §Perf): interpret-mode pallas lowers the grid to a
    sequential scan, so fewer/larger tiles amortize the per-step overhead —
    d=6.9M went 3.01s -> 2.27s/step moving 16 -> 240 blocks per tile. On a
    real TPU the cap would instead come from VMEM (tile bytes ~ cap*B_d*12).
    """
    return max(t for t in range(1, min(nb, cap) + 1) if nb % t == 0)


def emit_opt_steps(em: Emitter, d: int, opt: M.OptConfig, which=("microadam", "adamw", "adamw8bit")):
    nb = d // opt.block
    opt = dataclasses.replace(opt, tile_blocks=_pick_tile_blocks(nb))
    nq = d // opt.qbucket
    nq8 = d // M.QBUCKET8
    hyper = {
        "m": opt.m, "block": opt.block, "kb": opt.kb, "qbucket": opt.qbucket,
        "density": opt.density, "beta1": opt.beta1, "beta2": opt.beta2,
        "eps": opt.eps, "tile_blocks": opt.tile_blocks, "d": d, "nb": nb,
    }
    if "microadam" in which:
        fn = M.build_microadam_step(d, opt)
        em.emit(
            f"microadam_step_d{d}", fn,
            inputs=[("params", S((d,))), ("grads", S((d,))),
                    ("ef", S((d // 2,), U8)),
                    ("qlo", S((nq,))), ("qhi", S((nq,))),
                    ("w_idx", S((opt.m, nb, opt.kb), I32)),
                    ("w_val", S((opt.m, nb, opt.kb))),
                    ("t", S((), I32)), ("lr", S(())), ("wd", S(()))],
            outputs=["params", "ef", "qlo", "qhi", "w_idx", "w_val"],
            meta={"kind": "opt_step", "opt": "microadam", "hyper": hyper},
        )
    if "adamw" in which:
        fn = M.build_adamw_step(opt.beta1, opt.beta2, opt.eps)
        em.emit(
            f"adamw_step_d{d}", fn,
            inputs=[("params", S((d,))), ("grads", S((d,))),
                    ("m", S((d,))), ("v", S((d,))),
                    ("t", S((), I32)), ("lr", S(())), ("wd", S(()))],
            outputs=["params", "m", "v"],
            meta={"kind": "opt_step", "opt": "adamw", "hyper": hyper},
        )
    if "adamw8bit" in which:
        fn = M.build_adamw8bit_step(opt.beta1, opt.beta2, opt.eps)
        em.emit(
            f"adamw8bit_step_d{d}", fn,
            inputs=[("params", S((d,))), ("grads", S((d,))),
                    ("m8", S((d,), U8)), ("mscale", S((nq8,))),
                    ("v8", S((d,), U8)), ("vscale", S((nq8,))),
                    ("t", S((), I32)), ("lr", S(())), ("wd", S(()))],
            outputs=["params", "m8", "mscale", "v8", "vscale"],
            meta={"kind": "opt_step", "opt": "adamw8bit", "hyper": hyper},
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", nargs="*", default=["tiny", "small"],
                    help="transformer presets to emit (tiny/small/base)")
    ap.add_argument("--cnn-presets", nargs="*", default=["cnn_tiny", "cnn_small"])
    ap.add_argument("--force", action="store_true", help="re-lower even if files exist")
    args = ap.parse_args()

    opt = M.OptConfig()
    em = Emitter(args.out_dir, args.force)

    opt_dims = set()
    for preset in args.presets:
        d = emit_lm(em, preset, opt)
        opt_dims.add(d)
        # Classifier graphs only for the smaller presets (table-1 stand-in).
        if preset in ("tiny", "small"):
            emit_cls(em, preset, opt)
    for preset in args.cnn_presets:
        emit_cnn(em, preset, opt)
    # Optimizer step artifacts for every LM dimensionality (the e2e driver
    # runs MicroAdam/AdamW/AdamW-8bit fully AOT; other experiments use the
    # native rust optimizers on artifact gradients).
    for d in sorted(opt_dims):
        emit_opt_steps(em, d, opt)
    em.finish()


if __name__ == "__main__":
    main()
