"""L2: JAX compute graphs, AOT-lowered to HLO text for the rust runtime.

Three families of graphs, all pure functions of flat f32 parameter vectors so
the rust coordinator can own every buffer:

  * model fwd/bwd graphs — `(flat_params, batch...) -> (loss, flat_grads)`:
      - decoder-only transformer LM (next-token loss),
      - transformer classifier (synthetic-MNLI stand-in),
      - small CNN classifier (ImageNet stand-in);
  * optimizer step graphs — MicroAdam (Algorithm 1, calling the L1 Pallas
    kernels), AdamW and AdamW-8bit baselines;
  * parameter layout metadata (`param_spec`) shared with rust via
    artifacts/manifest.json: name, shape, flat offset and init scheme for
    every tensor, so rust can initialize parameters without python.

Everything here runs exactly once at `make artifacts`; nothing in this module
is on the training hot path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import microadam_pallas, quant_pallas

# ---------------------------------------------------------------------------
# Configs and presets
# ---------------------------------------------------------------------------

# Top-K block size: the paper requires B_d < 2^15 so block-relative indices
# fit int16; 4096 matches the CUDA implementation's regime and divides
# cleanly by the quantization bucket.
BLOCK = 4096
# EF quantization bucket (paper §B: bucket size 64).
QBUCKET = 64


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int
    n_classes: int = 3  # classifier head (MNLI has 3 labels)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class CnnConfig:
    name: str
    channels: tuple
    image: int
    in_channels: int
    n_classes: int
    batch: int


@dataclasses.dataclass(frozen=True)
class OptConfig:
    """Static hyper-parameters baked into the optimizer step artifacts."""
    m: int = 10          # sliding window size (paper default)
    block: int = BLOCK   # Top-K block B_d
    density: float = 0.01  # k = 1% (99% sparsity)
    qbucket: int = QBUCKET
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    # Parameter blocks per pallas grid step (interpret-mode scan
    # amortization / TPU VMEM tile size): the L1 perf knob.
    tile_blocks: int = 16

    @property
    def kb(self) -> int:
        return max(1, math.ceil(self.block * self.density))

    @property
    def tile(self) -> int:
        return self.tile_blocks * self.block


TRANSFORMER_PRESETS = {
    "tiny": TransformerConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=4,
                              d_ff=256, seq=32, batch=4),
    "small": TransformerConfig("small", vocab=8192, d_model=256, n_layers=6, n_heads=8,
                               d_ff=1024, seq=64, batch=8),
    # BERT-Base-scale (~110M); compile-only on this 1-core testbed unless
    # explicitly requested (see DESIGN.md substitutions).
    "base": TransformerConfig("base", vocab=32768, d_model=768, n_layers=12, n_heads=12,
                              d_ff=3072, seq=128, batch=8),
}

CNN_PRESETS = {
    "cnn_tiny": CnnConfig("cnn_tiny", channels=(16, 32), image=32, in_channels=3,
                          n_classes=10, batch=16),
    "cnn_small": CnnConfig("cnn_small", channels=(32, 64, 128), image=32, in_channels=3,
                           n_classes=100, batch=32),
}


def pad_to_block(n: int, block: int = BLOCK) -> int:
    """Round n up to a multiple of the Top-K block size."""
    return ((n + block - 1) // block) * block


def pad_to_tile(n: int, opt: OptConfig | None = None) -> int:
    """Round n up to a multiple of the optimizer kernel tile (TC * B_d)."""
    tile = (opt or OptConfig()).tile
    return ((n + tile - 1) // tile) * tile


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamEntry:
    name: str
    shape: tuple
    init: str       # "normal" | "zeros" | "ones"
    init_std: float

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


def transformer_param_spec(cfg: TransformerConfig, head: str) -> list[ParamEntry]:
    """Deterministic flat layout of the transformer parameters.

    head = "lm" ties the output projection to tok_emb (no extra tensor);
    head = "cls" appends a linear classifier over the mean-pooled features.
    """
    std = 0.02
    out_std = 0.02 / math.sqrt(2 * cfg.n_layers)  # GPT-2-style residual scaling
    spec = [
        ParamEntry("tok_emb", (cfg.vocab, cfg.d_model), "normal", std),
        ParamEntry("pos_emb", (cfg.seq, cfg.d_model), "normal", std),
    ]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        spec += [
            ParamEntry(p + "ln1.g", (cfg.d_model,), "ones", 0.0),
            ParamEntry(p + "ln1.b", (cfg.d_model,), "zeros", 0.0),
            ParamEntry(p + "attn.wqkv", (cfg.d_model, 3 * cfg.d_model), "normal", std),
            ParamEntry(p + "attn.bqkv", (3 * cfg.d_model,), "zeros", 0.0),
            ParamEntry(p + "attn.wo", (cfg.d_model, cfg.d_model), "normal", out_std),
            ParamEntry(p + "attn.bo", (cfg.d_model,), "zeros", 0.0),
            ParamEntry(p + "ln2.g", (cfg.d_model,), "ones", 0.0),
            ParamEntry(p + "ln2.b", (cfg.d_model,), "zeros", 0.0),
            ParamEntry(p + "mlp.w1", (cfg.d_model, cfg.d_ff), "normal", std),
            ParamEntry(p + "mlp.b1", (cfg.d_ff,), "zeros", 0.0),
            ParamEntry(p + "mlp.w2", (cfg.d_ff, cfg.d_model), "normal", out_std),
            ParamEntry(p + "mlp.b2", (cfg.d_model,), "zeros", 0.0),
        ]
    spec += [
        ParamEntry("lnf.g", (cfg.d_model,), "ones", 0.0),
        ParamEntry("lnf.b", (cfg.d_model,), "zeros", 0.0),
    ]
    if head == "cls":
        spec += [
            ParamEntry("cls.w", (cfg.d_model, cfg.n_classes), "normal", std),
            ParamEntry("cls.b", (cfg.n_classes,), "zeros", 0.0),
        ]
    return spec


def cnn_param_spec(cfg: CnnConfig) -> list[ParamEntry]:
    spec = []
    cin = cfg.in_channels
    for i, cout in enumerate(cfg.channels):
        fan_in = 3 * 3 * cin
        spec += [
            ParamEntry(f"conv{i}.w", (3, 3, cin, cout), "normal", math.sqrt(2.0 / fan_in)),
            ParamEntry(f"conv{i}.b", (cout,), "zeros", 0.0),
        ]
        cin = cout
    spec += [
        ParamEntry("fc.w", (cin, cfg.n_classes), "normal", math.sqrt(1.0 / cin)),
        ParamEntry("fc.b", (cfg.n_classes,), "zeros", 0.0),
    ]
    return spec


def spec_size(spec: list[ParamEntry]) -> int:
    return sum(e.size for e in spec)


def unflatten(flat: jnp.ndarray, spec: list[ParamEntry]) -> dict:
    """Slice the (padded) flat vector into named tensors (pure view ops)."""
    params = {}
    off = 0
    for e in spec:
        params[e.name] = jax.lax.dynamic_slice(flat, (off,), (e.size,)).reshape(e.shape)
        off += e.size
    return params


# ---------------------------------------------------------------------------
# Transformer forward passes
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(cfg: TransformerConfig, p, prefix, x, causal: bool):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = x @ p[prefix + "wqkv"] + p[prefix + "bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ p[prefix + "wo"] + p[prefix + "bo"]


def transformer_trunk(cfg: TransformerConfig, p, tokens, causal: bool):
    s = tokens.shape[1]
    x = p["tok_emb"][tokens] + p["pos_emb"][:s]
    for l in range(cfg.n_layers):
        pre = f"layer{l}."
        x = x + _attention(cfg, p, pre + "attn.",
                           _layer_norm(x, p[pre + "ln1.g"], p[pre + "ln1.b"]), causal)
        hcur = _layer_norm(x, p[pre + "ln2.g"], p[pre + "ln2.b"])
        hcur = jax.nn.gelu(hcur @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"])
        x = x + hcur @ p[pre + "mlp.w2"] + p[pre + "mlp.b2"]
    return _layer_norm(x, p["lnf.g"], p["lnf.b"])


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))


def lm_loss(cfg: TransformerConfig, spec, flat, tokens, targets):
    """Next-token cross entropy; output head tied to tok_emb."""
    p = unflatten(flat, spec)
    x = transformer_trunk(cfg, p, tokens, causal=True)
    logits = x @ p["tok_emb"].T
    return _xent(logits, targets)


def cls_loss(cfg: TransformerConfig, spec, flat, tokens, labels):
    """Sequence classification over mean-pooled trunk features."""
    p = unflatten(flat, spec)
    x = transformer_trunk(cfg, p, tokens, causal=True)
    feats = jnp.mean(x, axis=1)
    logits = feats @ p["cls.w"] + p["cls.b"]
    return _xent(logits, labels)


def cls_logits(cfg: TransformerConfig, spec, flat, tokens):
    p = unflatten(flat, spec)
    x = transformer_trunk(cfg, p, tokens, causal=True)
    feats = jnp.mean(x, axis=1)
    return feats @ p["cls.w"] + p["cls.b"]


# ---------------------------------------------------------------------------
# CNN forward pass
# ---------------------------------------------------------------------------

def cnn_logits(cfg: CnnConfig, spec, flat, images):
    p = unflatten(flat, spec)
    x = images
    for i in range(len(cfg.channels)):
        x = jax.lax.conv_general_dilated(
            x, p[f"conv{i}.w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + p[f"conv{i}.b"])
        # 2x2 max pool
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    feats = jnp.mean(x, axis=(1, 2))
    return feats @ p["fc.w"] + p["fc.b"]


def cnn_loss(cfg: CnnConfig, spec, flat, images, labels):
    return _xent(cnn_logits(cfg, spec, flat, images), labels)


# ---------------------------------------------------------------------------
# fwd/bwd graph builders (what actually gets AOT-lowered)
# ---------------------------------------------------------------------------

def build_fwdbwd(loss_fn: Callable) -> Callable:
    """(flat, *batch) -> (loss, flat_grads); grads w.r.t. the padded vector."""
    def fwdbwd(flat, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(flat, *batch)
        return loss, grads
    return fwdbwd


# ---------------------------------------------------------------------------
# Optimizer step graphs
# ---------------------------------------------------------------------------

def window_weights(t, m: int, beta1: float, beta2: float):
    """Folded per-row window weights; mirrors kernels.ref.window_weights_ref."""
    t = jnp.asarray(t, jnp.int32)
    w = jnp.mod(t - 1, m)
    rows = jnp.arange(m)
    age = jnp.mod(w - rows, m).astype(jnp.float32)
    valid = (rows < t).astype(jnp.float32)
    eff = jnp.minimum(t, m).astype(jnp.float32)

    def fold(beta):
        return valid * (1.0 - beta) * beta**age / (1.0 - beta**eff)

    return fold(beta1), fold(beta2)


def build_microadam_step(d: int, opt: OptConfig) -> Callable:
    """MicroAdam step over a (d,)-flat parameter vector (Algorithm 1).

    Inputs:  params f32[d], grads f32[d], ef u8[d/2], qlo f32[d/Bq],
             qhi f32[d/Bq], wI i32[m,NB,kb], wV f32[m,NB,kb], t i32[],
             lr f32[], wd f32[]
    Outputs: params', ef', qlo', qhi', wI', wV'
    t is the 1-based step counter; wd enables the Algorithm-4 decoupled
    weight-decay variant (pass 0 for plain MicroAdam).
    """
    assert d % opt.tile == 0 and d % opt.qbucket == 0
    nb = d // opt.block
    kb = opt.kb

    def step(params, grads, ef, qlo, qhi, w_idx, w_val, t, lr, wd):
        # Line 5: a <- g + Q^-1(e) — EF decompressed straight into the
        # gradient accumulator (the paper reuses the .grad buffer).
        ef_deq = quant_pallas.dequant4(ef, qlo, qhi, opt.qbucket, tile=opt.tile)
        acc = grads + ef_deq
        blocks = acc.reshape(nb, opt.block)
        # Line 6: block-wise Top-K on |a|. Implemented as a full sort-by-key
        # instead of lax.top_k: the TopK HLO op postdates the xla_extension
        # 0.5.1 text parser the rust runtime links against, while `sort`
        # round-trips fine (see aot._sanitize_hlo).
        iota = jnp.broadcast_to(jnp.arange(opt.block, dtype=jnp.int32), blocks.shape)
        _, sorted_idx = jax.lax.sort_key_val(-jnp.abs(blocks), iota, dimension=1)
        idx = sorted_idx[:, :kb]
        vals = jnp.take_along_axis(blocks, idx, axis=1)
        # Line 7: remove selected outliers from the accumulator.
        remainder = jax.vmap(lambda row, ii: row.at[ii].set(0.0))(blocks, idx)
        # Lines 8-9: quantize what is left (the new EF) to 4 bits.
        ef2, qlo2, qhi2 = quant_pallas.quant4(remainder.reshape(-1), opt.qbucket, tile=opt.tile)
        # Line 10: ring-buffer insert at row (t-1) % m.
        row = jnp.mod(t - 1, opt.m)
        w_idx2 = jax.lax.dynamic_update_slice(w_idx, idx[None], (row, 0, 0))
        w_val2 = jax.lax.dynamic_update_slice(w_val, vals[None], (row, 0, 0))
        # Lines 11-13 via the Pallas block kernel (AdamStats + update).
        w1, w2 = window_weights(t, opt.m, opt.beta1, opt.beta2)
        decayed = (1.0 - lr * wd) * params
        params2 = microadam_pallas.microadam_update(
            decayed, w_idx2, w_val2, w1, w2, lr, opt.eps, opt.block,
            tile_blocks=opt.tile_blocks)
        return params2, ef2, qlo2, qhi2, w_idx2, w_val2

    return step


def build_adamw_step(beta1=0.9, beta2=0.999, eps=1e-8) -> Callable:
    """Dense AdamW baseline: fp32 m/v state (8 bytes/param)."""
    def step(params, grads, m, v, t, lr, wd):
        m2 = beta1 * m + (1.0 - beta1) * grads
        v2 = beta2 * v + (1.0 - beta2) * grads * grads
        tf = t.astype(jnp.float32)
        m_hat = m2 / (1.0 - beta1**tf)
        v_hat = v2 / (1.0 - beta2**tf)
        params2 = (1.0 - lr * wd) * params - lr * m_hat / (jnp.sqrt(v_hat) + eps)
        return params2, m2, v2

    return step


# 8-bit state quantization bucket (Dettmers et al. use 2048/256 block sizes).
QBUCKET8 = 256


def _dyn_table_signed():
    """Log-spaced signed code table (Dettmers-style dynamic map): code 128 is
    exactly 0, codes above/below are +/- magnitudes over ~7 decades. Mirrors
    rust/src/quant Dynamic8::signed()."""
    t = [0.0] * 256
    for k in range(1, 128):
        mag = 10.0 ** (-7.0 * (127 - k) / 126.0)
        t[128 + k] = mag
        t[128 - k] = -mag
    t[0] = -1.0
    return jnp.asarray(t, jnp.float32)


def _dyn_table_unsigned():
    """Log-spaced unsigned table: code 0 = 0, codes 1..255 in (1e-7, 1]."""
    t = [0.0] + [10.0 ** (-7.0 * (255 - c) / 254.0) for c in range(1, 256)]
    return jnp.asarray(t, jnp.float32)


def _dyn_quant(x, bucket, table):
    """Bucket-absmax dynamic quantization: nearest table code per element."""
    nb = x.shape[0] // bucket
    xb = x.reshape(nb, bucket)
    absmax = jnp.max(jnp.abs(xb), axis=1)
    safe = jnp.where(absmax > 0, absmax, 1.0)
    y = xb / safe[:, None]
    hi = jnp.clip(jnp.searchsorted(table, y.reshape(-1)), 1, 255)
    lo = hi - 1
    pick_lo = (y.reshape(-1) - table[lo]) <= (table[hi] - y.reshape(-1))
    q = jnp.where(pick_lo, lo, hi).astype(jnp.uint8)
    return q, absmax


def _dyn_dequant(q, scale, bucket, table):
    nb = scale.shape[0]
    vals = table[q.astype(jnp.int32)].reshape(nb, bucket)
    return (vals * scale[:, None]).reshape(-1)


def build_adamw8bit_step(beta1=0.9, beta2=0.999, eps=1e-8, bucket=QBUCKET8) -> Callable:
    """AdamW with 8-bit block-quantized m/v state (2 bytes/param).

    Log-spaced dynamic code tables mirror Dettmers et al.'s dynamic-tree
    quantile map (same storage cost, relative precision over ~7 decades);
    a trust-region clip on the update guards the v-underflow corner.
    Bit-compatible with the rust-native AdamW8bit (quant::Dynamic8).
    """
    mtab = _dyn_table_signed()
    vtab = _dyn_table_unsigned()

    def step(params, grads, m8, mscale, v8, vscale, t, lr, wd):
        m = _dyn_dequant(m8, mscale, bucket, mtab)
        v = _dyn_dequant(v8, vscale, bucket, vtab)
        m2 = beta1 * m + (1.0 - beta1) * grads
        v2 = beta2 * v + (1.0 - beta2) * grads * grads
        tf = t.astype(jnp.float32)
        m_hat = m2 / (1.0 - beta1**tf)
        v_hat = v2 / (1.0 - beta2**tf)
        u = jnp.clip(m_hat / (jnp.sqrt(v_hat) + eps), -10.0, 10.0)
        params2 = (1.0 - lr * wd) * params - lr * u
        m8b, mscale2 = _dyn_quant(m2, bucket, mtab)
        v8b, vscale2 = _dyn_quant(v2, bucket, vtab)
        return params2, m8b, mscale2, v8b, vscale2

    return step
