"""Pallas 4-bit quantization kernels vs the pure-jnp oracle (+ Lemma 1).

hypothesis sweeps the kernel's shapes and value distributions; every case
asserts bit-exact code agreement with ref.py and the analytic error bounds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import quant_pallas as qp


def _rand(seed, n, scale=1.0, offset=0.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale + offset
    return x.astype(jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    buckets=st.integers(1, 16),
    bucket=st.sampled_from([4, 64, 128]),
    scale=st.floats(1e-3, 1e3),
)
def test_quant4_matches_ref(seed, buckets, bucket, scale):
    n = buckets * bucket
    x = _rand(seed, n, scale)
    p, lo, hi = qp.quant4(x, bucket, tile=n)
    pr, lor, hir = ref.quant4_ref(x, bucket)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(lo), np.asarray(lor), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(hi), np.asarray(hir), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), buckets=st.integers(1, 8), bucket=st.sampled_from([8, 64]))
def test_dequant4_roundtrip_error_bound(seed, buckets, bucket):
    """Nearest rounding: |deq(q(x)) - x| <= u/2 element-wise per bucket."""
    n = buckets * bucket
    x = _rand(seed, n)
    p, lo, hi = qp.quant4(x, bucket, tile=n)
    xd = qp.dequant4(p, lo, hi, bucket, tile=n)
    u = (np.asarray(hi) - np.asarray(lo)) / 15.0
    err = np.abs(np.asarray(xd) - np.asarray(x)).reshape(buckets, bucket)
    assert (err <= u[:, None] / 2 + 1e-6).all()


def test_quant4_multi_tile_grid():
    """Grid > 1: tiling must not change results vs a single-tile call."""
    n, bucket = 1024, 64
    x = _rand(7, n)
    p1, lo1, hi1 = qp.quant4(x, bucket, tile=n)
    p2, lo2, hi2 = qp.quant4(x, bucket, tile=n // 4)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(np.asarray(lo1), np.asarray(lo2))
    xd1 = qp.dequant4(p1, lo1, hi1, bucket, tile=n)
    xd2 = qp.dequant4(p1, lo1, hi1, bucket, tile=n // 4)
    np.testing.assert_allclose(np.asarray(xd1), np.asarray(xd2))


def test_quant4_constant_bucket_is_exact():
    """Delta == delta buckets must decode to the constant exactly."""
    bucket = 64
    x = jnp.full((bucket,), 3.25, jnp.float32)
    p, lo, hi = qp.quant4(x, bucket, tile=bucket)
    xd = qp.dequant4(p, lo, hi, bucket, tile=bucket)
    np.testing.assert_allclose(np.asarray(xd), np.asarray(x))


def test_quant4_preserves_min_max():
    """Bucket extremes quantize exactly (codes 0 and 15)."""
    bucket = 64
    x = _rand(11, bucket)
    p, lo, hi = qp.quant4(x, bucket, tile=bucket)
    xd = np.asarray(qp.dequant4(p, lo, hi, bucket, tile=bucket))
    i_lo = int(np.argmin(np.asarray(x)))
    i_hi = int(np.argmax(np.asarray(x)))
    assert xd[i_lo] == pytest.approx(float(np.min(np.asarray(x))), rel=1e-6)
    assert xd[i_hi] == pytest.approx(float(np.max(np.asarray(x))), rel=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_lemma1_stochastic_unbiased(seed):
    """Lemma 1: randomized rounding is unbiased — E[deq(q(x))] == x.

    Monte-Carlo over rounding keys; tolerance scales with u/sqrt(R).
    """
    bucket = 32
    x = _rand(seed, bucket)
    reps = 512
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), reps)

    def one(key):
        q, lo, hi = ref.quant_bucket_stochastic_ref(x, key, 4)
        return ref.dequant_bucket_ref(q, lo, hi, 4)

    mean = jnp.mean(jax.vmap(one)(keys), axis=0)
    u = float((jnp.max(x) - jnp.min(x)) / 15.0)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(x), atol=4 * u / np.sqrt(reps))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(3, 64))
def test_lemma1_norm_bound(seed, n):
    """Lemma 1 bound: ||q(x)-x|| <= sqrt(d-2)/(2^b-1) * (D-d)/sqrt(D^2+d^2) ||x||."""
    x = _rand(seed, n, scale=2.0)
    key = jax.random.PRNGKey(seed + 99)
    q, lo, hi = ref.quant_bucket_stochastic_ref(x, key, 4)
    xd = ref.dequant_bucket_ref(q, lo, hi, 4)
    err = float(jnp.linalg.norm(xd - x))
    lo_f, hi_f = float(lo), float(hi)
    denom = np.sqrt(hi_f**2 + lo_f**2)
    if denom == 0:
        return
    bound = np.sqrt(max(n - 2, 0)) / 15.0 * (hi_f - lo_f) / denom * float(jnp.linalg.norm(x))
    assert err <= bound + 1e-5
