"""MicroAdam Pallas block-update kernel vs the pure-jnp oracle.

hypothesis sweeps window size m, block count/size, k_b, tile factor and the
step counter (covering the warm-up t <= m regime and the steady state).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import microadam_pallas as mp
from compile.kernels import ref


def _case(seed, m, nb, bd, kb):
    kp, ki, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = jax.random.normal(kp, (nb * bd,), jnp.float32)
    # Top-K indices are distinct within a row per block; emulate via choice.
    idx = jnp.stack([
        jnp.stack([
            jax.random.choice(jax.random.fold_in(ki, i * nb + b), bd, (kb,), replace=False)
            for b in range(nb)
        ]) for i in range(m)
    ]).astype(jnp.int32)
    vals = jax.random.normal(kv, (m, nb, kb), jnp.float32)
    return params, idx, vals


def _ref_update(params, idx, vals, w1, w2, lr, eps, bd):
    nb = params.shape[0] // bd
    outs = []
    for b in range(nb):
        outs.append(ref.microadam_update_block_ref(
            params[b * bd:(b + 1) * bd], idx[:, b, :], vals[:, b, :], w1, w2, lr, eps))
    return jnp.concatenate(outs)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    m=st.integers(1, 12),
    nb=st.sampled_from([1, 2, 4]),
    bd=st.sampled_from([32, 128]),
    t=st.integers(1, 30),
)
def test_update_kernel_matches_ref(seed, m, nb, bd, t):
    kb = max(1, bd // 20)
    params, idx, vals = _case(seed, m, nb, bd, kb)
    w1, w2 = ref.window_weights_ref(t, m, 0.9, 0.999)
    out = mp.microadam_update(params, idx, vals, w1, w2, 0.01, 1e-8, bd, tile_blocks=1)
    expect = _ref_update(params, idx, vals, w1, w2, 0.01, 1e-8, bd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), tc=st.sampled_from([1, 2, 4]))
def test_update_kernel_tile_invariance(seed, tc):
    """Tile factor (the perf knob) must not change the numerics."""
    m, nb, bd = 5, 4, 64
    kb = 4
    params, idx, vals = _case(seed, m, nb, bd, kb)
    w1, w2 = ref.window_weights_ref(7, m, 0.9, 0.999)
    base = mp.microadam_update(params, idx, vals, w1, w2, 0.01, 1e-8, bd, tile_blocks=1)
    tiled = mp.microadam_update(params, idx, vals, w1, w2, 0.01, 1e-8, bd, tile_blocks=tc)
    np.testing.assert_allclose(np.asarray(base), np.asarray(tiled), atol=1e-6)


def test_window_weights_warmup_and_steady():
    """Validity masking at t <= m and ring ages in steady state."""
    m = 4
    # t=1: only row 0 valid, age 0, weight folds to exactly 1 after bias corr.
    w1, _ = M.window_weights(1, m, 0.9, 0.999)
    np.testing.assert_allclose(np.asarray(w1), [1.0, 0, 0, 0], atol=1e-6)
    # t=2: rows 0,1 valid; row written last (w = 1) has age 0.
    w1, _ = M.window_weights(2, m, 0.9, 0.999)
    a = np.asarray(w1)
    assert a[2] == 0 and a[3] == 0
    assert a[1] > a[0] > 0  # newest row outweighs older
    # steady state t=9 (w = 0): ages [0,3,2,1]
    w1, _ = M.window_weights(9, m, 0.9, 0.999)
    a = np.asarray(w1)
    order = np.argsort(-a)
    np.testing.assert_array_equal(order, [0, 3, 2, 1])
    # weights sum: sum_i (1-b) b^age / (1-b^m) == 1
    assert np.isclose(a.sum(), 1.0, atol=1e-6)


def test_window_weights_match_ref():
    for t in [1, 2, 5, 10, 11, 23]:
        for m in [1, 3, 10]:
            w1a, w2a = M.window_weights(t, m, 0.9, 0.999)
            w1b, w2b = ref.window_weights_ref(t, m, 0.9, 0.999)
            np.testing.assert_allclose(np.asarray(w1a), np.asarray(w1b), atol=1e-7)
            np.testing.assert_allclose(np.asarray(w2a), np.asarray(w2b), atol=1e-7)


def test_update_is_sparse_where_window_empty():
    """Parameters in coordinates never touched by the window must not move:
    the paper's sparse-update property (§3, Properties and Limitations)."""
    m, nb, bd, kb = 3, 1, 64, 2
    params = jnp.ones((bd,), jnp.float32)
    idx = jnp.array([[[0, 1]], [[2, 3]], [[0, 2]]], jnp.int32)
    vals = jnp.ones((m, 1, kb), jnp.float32)
    w1, w2 = ref.window_weights_ref(5, m, 0.9, 0.999)
    out = np.asarray(mp.microadam_update(params, idx, vals, w1, w2, 0.1, 1e-8, bd))
    touched = {0, 1, 2, 3}
    for j in range(bd):
        if j in touched:
            assert out[j] != 1.0
        else:
            assert out[j] == 1.0
