"""AOT emitter: HLO text well-formedness + manifest integrity."""

import json
import math
import os

import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    em = aot.Emitter(out, force=True)
    d = aot.emit_lm(em, "tiny", M.OptConfig())
    aot.emit_opt_steps(em, d, M.OptConfig(), which=("microadam", "adamw"))
    em.finish()
    return out, d


def test_hlo_text_parses_as_module(emitted):
    out, d = emitted
    for name in os.listdir(out):
        if not name.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(out, name)).read()
        assert "HloModule" in text, name
        assert "ENTRY" in text, name


def test_manifest_signature_consistency(emitted):
    out, d = emitted
    man = json.load(open(os.path.join(out, "manifest.json")))
    lm = man["artifacts"]["lm_tiny"]
    assert lm["kind"] == "fwdbwd"
    assert lm["inputs"][0]["shape"] == [d]
    layout = lm["layout"]
    # offsets are contiguous and cover d_model_params
    off = 0
    for p in layout["params"]:
        assert p["offset"] == off
        off += math.prod(p["shape"])
    assert off == layout["d_model_params"] <= layout["d_padded"] == d

    ma = man["artifacts"][f"microadam_step_d{d}"]
    h = ma["hyper"]
    assert h["d"] == d and h["d"] % h["block"] == 0
    assert h["kb"] == math.ceil(h["block"] * h["density"])
    # EF is half a byte per parameter: u8[d/2]
    ef = [i for i in ma["inputs"] if i["name"] == "ef"][0]
    assert ef["shape"] == [d // 2] and ef["dtype"] == "uint8"


def test_emitter_skips_existing_without_force(emitted, capsys):
    out, d = emitted
    em = aot.Emitter(out, force=False)
    aot.emit_lm(em, "tiny", M.OptConfig())
    assert "skipping" in capsys.readouterr().out
