"""L2 model graphs: layout integrity, shapes, loss/grad sanity."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def tiny():
    return M.TRANSFORMER_PRESETS["tiny"]


def _init_flat(spec, d, seed=0):
    key = jax.random.PRNGKey(seed)
    flat = np.zeros((d,), np.float32)
    off = 0
    for e in spec:
        key, sub = jax.random.split(key)
        if e.init == "normal":
            flat[off:off + e.size] = np.asarray(
                jax.random.normal(sub, (e.size,)) * e.init_std)
        elif e.init == "ones":
            flat[off:off + e.size] = 1.0
        off += e.size
    return jnp.asarray(flat)


def test_param_spec_offsets_contiguous(tiny):
    spec = M.transformer_param_spec(tiny, "lm")
    off = 0
    for e in spec:
        assert e.size == math.prod(e.shape)
        off += e.size
    assert off == M.spec_size(spec)
    # names unique
    names = [e.name for e in spec]
    assert len(set(names)) == len(names)


def test_unflatten_roundtrip(tiny):
    spec = M.transformer_param_spec(tiny, "lm")
    d = M.pad_to_tile(M.spec_size(spec))
    flat = jnp.arange(d, dtype=jnp.float32)
    params = M.unflatten(flat, spec)
    off = 0
    for e in spec:
        np.testing.assert_array_equal(
            np.asarray(params[e.name]).reshape(-1),
            np.arange(off, off + e.size, dtype=np.float32))
        off += e.size


def test_lm_loss_and_grads(tiny):
    spec = M.transformer_param_spec(tiny, "lm")
    d = M.pad_to_tile(M.spec_size(spec))
    flat = _init_flat(spec, d)
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (tiny.batch, tiny.seq), 0, tiny.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    fn = M.build_fwdbwd(lambda f, tok, tgt: M.lm_loss(tiny, spec, f, tok, tgt))
    loss, grads = jax.jit(fn)(flat, tokens, targets)
    assert np.isfinite(float(loss))
    # random init => loss near ln(vocab)
    assert abs(float(loss) - math.log(tiny.vocab)) < 1.0
    g = np.asarray(grads)
    assert g.shape == (d,)
    assert np.isfinite(g).all()
    assert np.abs(g[:M.spec_size(spec)]).max() > 0
    # padding lanes receive exactly zero gradient
    assert np.abs(g[M.spec_size(spec):]).max() == 0


def test_cls_loss_and_grads(tiny):
    spec = M.transformer_param_spec(tiny, "cls")
    d = M.pad_to_tile(M.spec_size(spec))
    flat = _init_flat(spec, d)
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (tiny.batch, tiny.seq), 0, tiny.vocab)
    labels = jax.random.randint(key, (tiny.batch,), 0, tiny.n_classes)
    loss, grads = jax.jit(M.build_fwdbwd(
        lambda f, tok, lab: M.cls_loss(tiny, spec, f, tok, lab)))(flat, tokens, labels)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - math.log(tiny.n_classes)) < 0.5
    assert np.isfinite(np.asarray(grads)).all()


def test_cls_logits_shape(tiny):
    spec = M.transformer_param_spec(tiny, "cls")
    d = M.pad_to_tile(M.spec_size(spec))
    flat = _init_flat(spec, d)
    tokens = jnp.zeros((tiny.batch, tiny.seq), jnp.int32)
    logits = jax.jit(lambda f, t: M.cls_logits(tiny, spec, f, t))(flat, tokens)
    assert logits.shape == (tiny.batch, tiny.n_classes)


def test_cnn_loss_and_grads():
    cfg = M.CNN_PRESETS["cnn_tiny"]
    spec = M.cnn_param_spec(cfg)
    d = M.pad_to_tile(M.spec_size(spec))
    flat = _init_flat(spec, d)
    key = jax.random.PRNGKey(3)
    images = jax.random.normal(key, (cfg.batch, cfg.image, cfg.image, cfg.in_channels))
    labels = jax.random.randint(key, (cfg.batch,), 0, cfg.n_classes)
    loss, grads = jax.jit(M.build_fwdbwd(
        lambda f, img, lab: M.cnn_loss(cfg, spec, f, img, lab)))(flat, images, labels)
    assert np.isfinite(float(loss))
    # random init: loss within a few nats of uniform prediction
    assert math.log(cfg.n_classes) * 0.5 < float(loss) < math.log(cfg.n_classes) + 4.0
    assert np.isfinite(np.asarray(grads)).all()


def test_lm_training_reduces_loss(tiny):
    """A few full-batch Adam steps on one fixed batch must overfit it."""
    spec = M.transformer_param_spec(tiny, "lm")
    d = M.pad_to_tile(M.spec_size(spec))
    flat = _init_flat(spec, d)
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (tiny.batch, tiny.seq), 0, tiny.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    fwdbwd = jax.jit(M.build_fwdbwd(lambda f, tok, tgt: M.lm_loss(tiny, spec, f, tok, tgt)))
    adam = jax.jit(M.build_adamw_step())
    m = jnp.zeros((d,))
    v = jnp.zeros((d,))
    losses = []
    for t in range(1, 21):
        loss, g = fwdbwd(flat, tokens, targets)
        losses.append(float(loss))
        flat, m, v = adam(flat, g, m, v, jnp.int32(t), jnp.float32(1e-2), jnp.float32(0.0))
    assert losses[-1] < losses[0] * 0.7, losses
