"""Optimizer step graphs (the exact functions that get AOT-lowered).

The MicroAdam graph is validated against a straight-line jnp re-derivation
of Algorithm 1 (dense EF, no packing) run step by step, and against
behavioural invariants: EF evolution, window ring semantics, convergence on
a quadratic, and the weight-decay variant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

OPT = M.OptConfig(m=4, block=64, density=0.05, qbucket=16, tile_blocks=2)
D = 256  # 4 blocks of 64, 2 tiles


def _state(d, opt):
    nb = d // opt.block
    nq = d // opt.qbucket
    return dict(
        ef=jnp.zeros((d // 2,), jnp.uint8),
        qlo=jnp.zeros((nq,), jnp.float32),
        qhi=jnp.zeros((nq,), jnp.float32),
        w_idx=jnp.zeros((opt.m, nb, opt.kb), jnp.int32),
        w_val=jnp.zeros((opt.m, nb, opt.kb), jnp.float32),
    )


def _dense_reference_step(params, grads, ef_dense, w_idx, w_val, t, lr, opt, wd=0.0):
    """Algorithm 1 with a *dense float* EF (no quantization) as the oracle
    for everything except the quantization error itself."""
    nb = params.shape[0] // opt.block
    acc = grads + ef_dense
    blocks = acc.reshape(nb, opt.block)
    _, idx = jax.lax.top_k(jnp.abs(blocks), opt.kb)
    idx = idx.astype(jnp.int32)
    vals = jnp.take_along_axis(blocks, idx, axis=1)
    rem = jax.vmap(lambda row, ii: row.at[ii].set(0.0))(blocks, idx)
    ef2 = rem.reshape(-1)
    row = (t - 1) % opt.m
    w_idx = w_idx.at[row].set(idx)
    w_val = w_val.at[row].set(vals)
    w1, w2 = ref.window_weights_ref(t, opt.m, opt.beta1, opt.beta2)
    outs = []
    for b in range(nb):
        outs.append(ref.microadam_update_block_ref(
            ((1.0 - lr * wd) * params)[b * opt.block:(b + 1) * opt.block],
            w_idx[:, b, :], w_val[:, b, :], w1, w2, lr, opt.eps))
    return jnp.concatenate(outs), ef2, w_idx, w_val


def test_microadam_graph_tracks_dense_reference():
    """Over several steps, the quantized-EF graph must stay within the
    accumulated 4-bit quantization tolerance of the dense-EF oracle."""
    step = jax.jit(M.build_microadam_step(D, OPT))
    key = jax.random.PRNGKey(0)
    params = jax.random.normal(key, (D,), jnp.float32)
    params_ref = params
    st = _state(D, OPT)
    ef_dense = jnp.zeros((D,), jnp.float32)
    w_idx_r = st["w_idx"]
    w_val_r = st["w_val"]
    lr = 0.01
    for t in range(1, 9):
        key, sub = jax.random.split(key)
        g = jax.random.normal(sub, (D,), jnp.float32)
        params, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"] = step(
            params, g, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"],
            jnp.int32(t), jnp.float32(lr), jnp.float32(0.0))
        params_ref, ef_dense, w_idx_r, w_val_r = _dense_reference_step(
            params_ref, g, ef_dense, w_idx_r, w_val_r, t, lr, OPT)
        # 4-bit EF error per coordinate is <= u/2; over a handful of steps the
        # parameter trajectories stay close.
        np.testing.assert_allclose(
            np.asarray(params), np.asarray(params_ref), atol=5e-2)


def test_microadam_graph_first_step_exact():
    """At t=1 EF is zero, so quantization has no effect yet: graph == oracle."""
    step = jax.jit(M.build_microadam_step(D, OPT))
    key = jax.random.PRNGKey(3)
    params = jax.random.normal(key, (D,), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 1), (D,), jnp.float32)
    st = _state(D, OPT)
    p2, *_ = step(params, g, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"],
                  jnp.int32(1), jnp.float32(0.01), jnp.float32(0.0))
    p_ref, _, _, _ = _dense_reference_step(
        params, g, jnp.zeros((D,)), st["w_idx"], st["w_val"], 1, 0.01, OPT)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(p_ref), atol=1e-6)


def test_microadam_window_ring_overwrites_oldest():
    step = jax.jit(M.build_microadam_step(D, OPT))
    st = _state(D, OPT)
    params = jnp.zeros((D,), jnp.float32)
    rows_seen = []
    for t in range(1, OPT.m + 2):
        g = jax.random.normal(jax.random.PRNGKey(t), (D,), jnp.float32)
        params, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"] = step(
            params, g, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"],
            jnp.int32(t), jnp.float32(0.0), jnp.float32(0.0))
        rows_seen.append(np.asarray(st["w_val"]).copy())
    # After m+1 steps, row 0 must have been overwritten (t=m+1 -> row 0):
    assert not np.allclose(rows_seen[-1][0], rows_seen[0][0])
    # and rows 1..m-1 are unchanged from their last write.
    np.testing.assert_allclose(rows_seen[-1][1:], rows_seen[-2][1:])


def test_microadam_ef_captures_unselected_mass():
    """After one step, dequantized EF ~= accumulator minus Top-K outliers."""
    step = jax.jit(M.build_microadam_step(D, OPT))
    st = _state(D, OPT)
    key = jax.random.PRNGKey(5)
    params = jnp.zeros((D,), jnp.float32)
    g = jax.random.normal(key, (D,), jnp.float32)
    _, ef, qlo, qhi, w_idx, w_val = step(
        params, g, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"],
        jnp.int32(1), jnp.float32(0.01), jnp.float32(0.0))
    ef_deq = ref.dequant4_ref(ef, qlo, qhi, OPT.qbucket)
    # expected remainder
    blocks = g.reshape(-1, OPT.block)
    _, idx = jax.lax.top_k(jnp.abs(blocks), OPT.kb)
    rem = jax.vmap(lambda row, ii: row.at[ii].set(0.0))(blocks, idx.astype(jnp.int32))
    expected = np.asarray(rem.reshape(-1))
    u = (np.asarray(qhi) - np.asarray(qlo)) / 15.0
    err = np.abs(np.asarray(ef_deq) - expected).reshape(-1, OPT.qbucket)
    assert (err <= u[:, None] / 2 + 1e-6).all()


def test_microadam_converges_on_quadratic():
    """f(x) = ||x||^2/2: MicroAdam must drive the iterate toward zero."""
    step = jax.jit(M.build_microadam_step(D, OPT))
    st = _state(D, OPT)
    x = jax.random.normal(jax.random.PRNGKey(7), (D,), jnp.float32)
    n0 = float(jnp.linalg.norm(x))
    for t in range(1, 201):
        g = x  # grad of ||x||^2/2
        x, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"] = step(
            x, g, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"],
            jnp.int32(t), jnp.float32(0.05), jnp.float32(0.0))
    assert float(jnp.linalg.norm(x)) < 0.25 * n0


def test_microadam_weight_decay_shrinks_params():
    """wd > 0 with zero gradients must contract the parameters (Alg 4)."""
    step = jax.jit(M.build_microadam_step(D, OPT))
    st = _state(D, OPT)
    x = jnp.ones((D,), jnp.float32)
    g = jnp.zeros((D,), jnp.float32)
    x2, *_ = step(x, g, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"],
                  jnp.int32(1), jnp.float32(0.1), jnp.float32(0.5))
    # (1 - lr*wd) = 0.95 contraction, no gradient-driven update
    np.testing.assert_allclose(np.asarray(x2), 0.95 * np.ones(D), atol=1e-6)


def test_adamw_graph_matches_oracle():
    step = jax.jit(M.build_adamw_step())
    key = jax.random.PRNGKey(11)
    p = jax.random.normal(key, (D,))
    m = jnp.zeros((D,))
    v = jnp.zeros((D,))
    pr, mr, vr = p, m, v
    for t in range(1, 6):
        g = jax.random.normal(jax.random.fold_in(key, t), (D,))
        p, m, v = step(p, g, m, v, jnp.int32(t), jnp.float32(1e-3), jnp.float32(0.01))
        pr, mr, vr = ref.adamw_step_ref(pr, g, mr, vr, t, 1e-3, weight_decay=0.01)
        np.testing.assert_allclose(np.asarray(p), np.asarray(pr), atol=1e-6)


def test_adamw8bit_tracks_fp32_adamw():
    """8-bit state quantization stays close to fp32 AdamW over steps."""
    step8 = jax.jit(M.build_adamw8bit_step())
    step32 = jax.jit(M.build_adamw_step())
    d = 512  # multiple of the 8-bit bucket (256)
    key = jax.random.PRNGKey(13)
    p8 = p32 = jax.random.normal(key, (d,))
    m8 = jnp.full((d,), 128, jnp.uint8)
    ms = jnp.zeros((d // M.QBUCKET8,))
    v8 = jnp.zeros((d,), jnp.uint8)
    vs = jnp.zeros((d // M.QBUCKET8,))
    m32 = jnp.zeros((d,))
    v32 = jnp.zeros((d,))
    for t in range(1, 11):
        g = jax.random.normal(jax.random.fold_in(key, t), (d,))
        p8, m8, ms, v8, vs = step8(p8, g, m8, ms, v8, vs,
                                   jnp.int32(t), jnp.float32(1e-3), jnp.float32(0.0))
        p32, m32, v32 = step32(p32, g, m32, v32,
                               jnp.int32(t), jnp.float32(1e-3), jnp.float32(0.0))
    # 8-bit requantization error compounds per step (~scale/2 each on m/v);
    # over 10 steps with lr=1e-3 the trajectories stay within ~1e-2.
    np.testing.assert_allclose(np.asarray(p8), np.asarray(p32), atol=1.5e-2)
    # and still far closer to fp32-AdamW than to doing nothing:
    assert float(jnp.linalg.norm(p8 - p32)) < 0.1 * float(jnp.linalg.norm(p8))


def test_microadam_update_density_property():
    """Paper §3 'Properties': with disjoint window rows, update density is at
    most m * k / d; coordinates outside the window union don't move."""
    opt = M.OptConfig(m=2, block=64, density=0.05, qbucket=16, tile_blocks=1)
    d = 128
    step = jax.jit(M.build_microadam_step(d, opt))
    nb = d // opt.block
    st = _state(d, opt)
    x = jnp.zeros((d,), jnp.float32)
    moved = np.zeros((d,), bool)
    for t in range(1, 3):
        g = jax.random.normal(jax.random.PRNGKey(100 + t), (d,), jnp.float32)
        x2, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"] = step(
            x, g, st["ef"], st["qlo"], st["qhi"], st["w_idx"], st["w_val"],
            jnp.int32(t), jnp.float32(0.01), jnp.float32(0.0))
        moved |= np.asarray(x2 != x)
        x = x2
    max_density = opt.m * opt.kb * nb / d
    assert moved.mean() <= max_density + 1e-9
