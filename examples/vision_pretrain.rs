//! ImageNet-shaped CNN pre-training comparison (Table 4 scenario).
//!
//! Pre-trains the CNN artifact from scratch on the synthetic image set with
//! SGD / AdamW / AdamW-8bit / MicroAdam and prints the paper-style rows,
//! including the exact paper-scale ResNet state sizes.
//!
//! Run: `make artifacts && cargo run --release --example vision_pretrain
//!       [-- --steps 150 --model cnn_tiny]`

fn arg(name: &str, default: &str) -> String {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let model = arg("--model", "cnn_tiny");
    let steps: u64 = arg("--steps", "150").parse()?;
    microadam::bench::run_table4(&arg("--artifacts", "artifacts"), "runs", &model, steps)
}
