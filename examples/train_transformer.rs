//! End-to-end driver (DESIGN.md §5): full three-layer training run.
//!
//! Loads the `lm_small` fwd/bwd artifact (JAX transformer, lowered once to
//! HLO) and the `microadam_step_*` artifact (Pallas kernels inside), trains
//! on a synthetic Markov corpus for a few hundred steps with the whole hot
//! path in rust + PJRT, logs the loss curve to `runs/e2e_*.jsonl`, and
//! reports throughput plus the optimizer-state comparison vs AdamW/AdamW-8b.
//!
//! Run: `make artifacts && cargo run --release --example train_transformer
//!       [-- --steps 300 --model lm_small --optimizer micro-adam]`

use std::time::Instant;

use microadam::coordinator::config::{parse_optimizer, OptBackend, TrainConfig};
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::coordinator::trainer::Trainer;

fn arg(name: &str, default: &str) -> String {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let model = arg("--model", "lm_small");
    let steps: u64 = arg("--steps", "300").parse()?;
    let optimizer = parse_optimizer(&arg("--optimizer", "micro-adam"))?;
    let artifacts = arg("--artifacts", "artifacts");

    let cfg = TrainConfig {
        model: model.clone(),
        optimizer,
        backend: OptBackend::Aot,
        schedule: LrSchedule::WarmupCosine {
            lr: arg("--lr", "1e-3").parse()?,
            warmup: steps / 20,
            total: steps,
            floor_frac: 0.1,
        },
        steps,
        seed: 7,
        out: format!("runs/e2e_{model}_{optimizer:?}.jsonl").to_lowercase(),
        log_every: (steps / 20).max(1),
        artifacts_dir: artifacts,
        ..Default::default()
    };
    println!("e2e driver: {model} + {optimizer:?} (AOT, python-free hot path), {steps} steps");

    let mut trainer = Trainer::new(cfg)?;
    let d = trainer.layout.d_padded;
    let d_model = trainer.layout.d_model;
    println!(
        "model params: {d_model} ({d} padded), opt state: {} bytes",
        trainer.opt_state_bytes()
    );

    let mut logger = MetricsLogger::new(&trainer.cfg.out)?;
    let t0 = Instant::now();
    trainer.train(&mut logger)?;
    let dt = t0.elapsed().as_secs_f64();

    // tokens/s: batch * seq per step
    let meta = trainer.runtime_mut().meta(&model)?.clone();
    let tokens_per_step = (meta.inputs[1].2[0] * meta.inputs[1].2[1]) as f64;
    println!(
        "\nloss: {:.4} -> {:.4} (tail-10 mean) over {steps} steps",
        logger.first_loss(),
        logger.tail_loss(10)
    );
    println!(
        "throughput: {:.2} steps/s, {:.0} tokens/s on 1 CPU core",
        steps as f64 / dt,
        steps as f64 * tokens_per_step / dt
    );
    println!("loss curve: {}", trainer.cfg.out);

    // Optimizer-state comparison at this model size (paper dtypes).
    let dm = d as u64;
    println!("\noptimizer state at d = {dm} (paper dtypes):");
    println!("  AdamW fp32  {:>12} B", microadam::memory::adamw_fp32(dm));
    println!("  AdamW-8bit  {:>12} B", microadam::memory::adamw_8bit(dm));
    println!(
        "  MicroAdam   {:>12} B (this run: {} B)",
        microadam::memory::microadam_default(dm),
        trainer.opt_state_bytes()
    );
    assert!(logger.tail_loss(10) < logger.first_loss(), "training must reduce the loss");
    Ok(())
}
