//! GLUE/MNLI-shaped fine-tuning comparison (Table 1 scenario).
//!
//! Fine-tunes the transformer classifier artifact on the synthetic NLI task
//! with all five Table-1 optimizers and prints the paper-style rows
//! (train loss / accuracy / optimizer-state memory).
//!
//! Run: `make artifacts && cargo run --release --example finetune_glue
//!       [-- --steps 150 --model cls_tiny]`

fn arg(name: &str, default: &str) -> String {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let model = arg("--model", "cls_tiny");
    let steps: u64 = arg("--steps", "150").parse()?;
    microadam::bench::run_table1(&arg("--artifacts", "artifacts"), "runs", &model, steps)
}
