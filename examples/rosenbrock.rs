//! Figure 1 + Figure 9 driver: optimization trajectories on the paper's 2-D
//! test functions.
//!
//! Writes CSV trajectories under `runs/` (plot with any tool) and prints the
//! endpoint summaries. Run:
//! `cargo run --release --example rosenbrock`

fn main() -> anyhow::Result<()> {
    microadam::bench::run_fig1("runs", 1500)?;
    microadam::bench::run_fig9("runs", 1500)?;
    Ok(())
}
