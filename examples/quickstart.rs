//! Quickstart: the library API in ~60 lines, no artifacts needed.
//!
//! Trains a small native MLP on the synthetic NLI task with MicroAdam and
//! with AdamW, and prints the loss curves plus the optimizer-state memory
//! each one needs — the paper's trade-off in miniature.
//!
//! Run: `cargo run --release --example quickstart`

use microadam::data::NliDataset;
use microadam::models::mlp::Mlp;
use microadam::optim::adamw::{AdamW, AdamWConfig};
use microadam::optim::microadam::{MicroAdam, MicroAdamConfig};
use microadam::optim::Optimizer;

fn main() {
    let vocab = 128;
    let mlp = Mlp::new(vec![vocab, 64, 3]);
    println!("model: MLP {:?}, {} params", mlp.sizes, mlp.dim());

    let mut results = Vec::new();
    for which in ["microadam", "adamw"] {
        let mut opt: Box<dyn Optimizer> = match which {
            "microadam" => Box::new(MicroAdam::new(mlp.dim(), MicroAdamConfig::default())),
            _ => Box::new(AdamW::new(mlp.dim(), AdamWConfig::default())),
        };
        let mut flat = mlp.init(7);
        let mut ds = NliDataset::new(vocab, 3, 0);
        let (mut toks, mut labs, mut feats) = (vec![], vec![], vec![]);
        let mut grads = vec![0f32; mlp.dim()];
        let mut first = 0f32;
        let mut last = 0f32;
        for step in 1..=300 {
            ds.next_batch(16, 24, &mut toks, &mut labs);
            Mlp::featurize_tokens(vocab, &toks, 24, &mut feats);
            let loss = mlp.loss_grad(&flat, &feats, &labs, &mut grads);
            opt.step(&mut flat, &grads, 3e-3);
            if step == 1 {
                first = loss;
            }
            last = loss;
            if step % 75 == 0 {
                println!("  [{which}] step {step:>3}  loss {loss:.4}");
            }
        }
        ds.next_batch(256, 24, &mut toks, &mut labs);
        Mlp::featurize_tokens(vocab, &toks, 24, &mut feats);
        let acc = mlp.accuracy(&flat, &feats, &labs);
        println!(
            "{which:>10}: loss {first:.3} -> {last:.3}, acc {:.1}%, opt state {} B (paper dtypes: {} B)",
            acc * 100.0,
            opt.state_bytes(),
            opt.paper_state_bytes()
        );
        results.push((which, acc, opt.paper_state_bytes()));
    }
    let (micro, adam) = (&results[0], &results[1]);
    println!(
        "\nMicroAdam matches AdamW accuracy ({:.1}% vs {:.1}%) with {:.1}x less optimizer state",
        micro.1 * 100.0,
        adam.1 * 100.0,
        adam.2 as f64 / micro.2 as f64
    );
}
