//! Data-parallel pre-training demo: N replicas, compressed gradient
//! exchange, one shared MicroAdam step — the setting MicroAdam's error
//! feedback was born in.
//!
//! Runs on the stub runtime (the native MLP workload needs no artifacts):
//!
//! ```text
//! cargo run --release --example dist_pretrain -- --ranks 4 --reduce eftopk
//! ```
//!
//! Compare reducers: `--reduce dense` (exact, 4 B/param on the wire),
//! `--reduce topk` (sparse, biased), `--reduce eftopk` (sparse + 4-bit
//! error feedback — tracks dense at a fraction of the bytes).
//!
//! This example runs the in-process (loopback) topology; the gradients
//! still travel through the real wire frames (`dist::wire`), so the
//! reported MB are measured framed bytes. For true multi-process runs —
//! one OS process per rank over Unix sockets or shared memory — use the
//! launcher: `microadam train --ranks 4 --reduce eftopk --transport uds`
//! (bit-identical to this loopback run with the same seeds; see
//! rust/src/dist/README.md for the wire-format spec).

use microadam::coordinator::config::TrainConfig;
use microadam::coordinator::metrics::MetricsLogger;
use microadam::coordinator::schedule::LrSchedule;
use microadam::dist::{parse_reducer, DistTrainer};

fn arg(name: &str, default: &str) -> String {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let ranks: usize = arg("--ranks", "4").parse()?;
    let steps: u64 = arg("--steps", "120").parse()?;
    let reduce = parse_reducer(&arg("--reduce", "eftopk"))?;

    let cfg = TrainConfig {
        model: arg("--model", "mlp_small"),
        schedule: LrSchedule::Const { lr: arg("--lr", "3e-3").parse()? },
        steps,
        seed: 7,
        log_every: (steps / 10).max(1),
        ranks,
        reduce,
        ..Default::default()
    };

    let mut trainer = DistTrainer::new(cfg)?;
    println!(
        "dist pre-train: {} ranks, reducer {}, d = {}, {} steps",
        trainer.ranks,
        trainer.reducer_name(),
        trainer.dim(),
        steps
    );
    let mut logger = MetricsLogger::new("")?;
    trainer.train(&mut logger)?;
    println!(
        "loss {:.4} -> {:.4} | {:.3} MB framed on the wire ({} B/rank/step) | \
         reducer residual {} B | opt state {} B",
        logger.first_loss(),
        logger.tail_loss(10),
        trainer.wire_bytes_total() as f64 / (1u64 << 20) as f64,
        trainer.frame_bytes_per_rank(),
        trainer.reducer_state_bytes(),
        trainer.opt_state_bytes(),
    );
    Ok(())
}
